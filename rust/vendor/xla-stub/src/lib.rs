//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate links the PJRT C API and is unavailable in offline
//! build environments, so this stub keeps `edgerag::runtime`'s PJRT path
//! *compiling* while failing cleanly at runtime: `PjRtClient::cpu()`
//! returns an error, which the compute service catches to fall back to the
//! pure-rust reference backend (`edgerag::runtime::reference`). Replace the
//! `xla` path dependency in the root `Cargo.toml` with the real crate to
//! enable genuine PJRT execution; every signature here mirrors the call
//! sites in `rust/src/runtime/executable.rs` and `runtime/mod.rs`.

use std::fmt;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT is unavailable: built against the offline xla stub \
         (rust/vendor/xla-stub); the runtime falls back to the reference \
         compute backend"
            .to_string(),
    )
}

/// Stub PJRT client: construction always fails.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        // Unreachable in practice: no PjRtLoadedExecutable can be
        // constructed through this stub.
        unreachable!("xla stub: no executable can exist")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stub XLA computation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub host literal.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}
