//! Offline shim for the `anyhow` crate.
//!
//! The build environments this repo targets do not always have a crates.io
//! registry available, so the workspace vendors the small slice of the
//! anyhow API the codebase actually uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Error chains are stored as flattened strings — `{e}` prints the
//! outermost context, `{e:#}` prints the whole chain separated by `: `,
//! matching anyhow's observable formatting for the call sites in this
//! repository. Swap the path dependency in the root `Cargo.toml` for the
//! real crate when a registry is available; no call sites need to change.

use std::error::Error as StdError;
use std::fmt;

/// A flattened error chain. `chain[0]` is the outermost (most recent)
/// context message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The flattened context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, like anyhow's alternate format.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a caused-by list.
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow::Error, this type deliberately does NOT
// implement std::error::Error — that is what makes the blanket `From`
// below coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — result with a boxed-chain error by default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!(fmt, ...)` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!(fmt, ...)` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, fmt, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).is_err());
        assert!(format!("{:#}", f(50).unwrap_err()).contains("50"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
