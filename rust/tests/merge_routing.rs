//! Property tests for cross-shard merge routing
//! (`rust/src/index/updates.rs` + `rust/src/index/shard.rs`).
//!
//! The tentpole guarantee: a drained cluster's merge victim is the
//! **global** nearest active neighbour — bit-for-bit the unsharded
//! oracle's choice — for any shard count and any ownership permutation
//! the online rebalancer can produce. And the rebalance planner composes
//! safely with merges: its input excludes tombstoned clusters, and a
//! stale plan naming a since-merged cluster skips it at execution time
//! instead of resurrecting or double-moving it.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::data::Rng;
use edgerag::index::{plan_rebalance, EdgeIndex, ShardedEdgeIndex, VectorIndex};
use edgerag::testutil::{shared_compute, test_seed};

fn builder(shards: usize, tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-mroute-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    b
}

/// Every chunk currently routed to global cluster `g` (public-API
/// membership discovery: the corpus plus churn ids are scanned through
/// `cluster_of`).
fn members_of(sharded: &ShardedEdgeIndex, g: u32, id_ceiling: u32) -> Vec<u32> {
    (0..id_ceiling)
        .filter(|&id| sharded.cluster_of(id) == Some(g))
        .collect()
}

#[test]
fn merge_victim_matches_oracle_for_any_placement() {
    // For shards ∈ {1, 2, 3, 4, 8} and several seeded ownership
    // permutations (random migrations), the sharded victim choice must
    // equal the unsharded oracle's for every global cluster — including
    // after merges have tombstoned some of them (victim selection must
    // skip tombstones identically).
    let seed = test_seed(0x4EE7);
    // shards = 1 builds a plain EdgeIndex (no routing to test); the
    // degenerate case is covered by the churn suite's shards=1 legs.
    for shards in [2usize, 3, 4, 8] {
        let b_o = builder(1, &format!("vic-oracle-{shards}"));
        let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut oracle, _mem_o) = b_o.index(&built_o, IndexKind::EdgeRag).unwrap();

        let b = builder(shards, &format!("vic-{shards}"));
        let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (subject, _mem_s) = b.index(&built, IndexKind::EdgeRag).unwrap();

        let mut rng = Rng::new(seed ^ shards as u64);
        for round in 0..3 {
            {
                let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
                // A fresh seeded ownership permutation each round.
                let globals: Vec<u32> = sharded
                    .cluster_loads()
                    .iter()
                    .flatten()
                    .map(|c| c.global)
                    .collect();
                for _ in 0..globals.len() * 2 {
                    let g = globals[rng.below(globals.len())];
                    sharded
                        .migrate_cluster(g, rng.below(sharded.shards()))
                        .unwrap();
                }
                sharded.verify_integrity().unwrap();

                let oracle_edge = oracle.as_any().downcast_ref::<EdgeIndex>().unwrap();
                let total = oracle_edge.clusters().n_clusters() as u32;
                for g in 0..total {
                    assert_eq!(
                        oracle_edge.merge_victim(g).unwrap(),
                        sharded.merge_victim(g).unwrap(),
                        "round {round}: victim of cluster {g} diverged at {shards} shards"
                    );
                }
            }

            // Tombstone one cluster on both replicas (drain the currently
            // smallest through the merge threshold) so the next round's
            // victim selection must mask it identically.
            let victim_chunks = {
                let oracle_edge = oracle.as_any().downcast_ref::<EdgeIndex>().unwrap();
                oracle_edge
                    .clusters()
                    .clusters
                    .iter()
                    .filter(|m| !m.is_empty())
                    .min_by_key(|m| (m.len(), m.id))
                    .map(|m| m.chunk_ids.clone())
                    .unwrap()
            };
            for id in victim_chunks {
                assert!(oracle.remove_chunk(id).unwrap());
                assert!(subject.remove_chunk_concurrent(id).unwrap());
            }
        }
    }
}

#[test]
fn planner_input_excludes_merged_clusters() {
    // The planner can never schedule a migration for a merged (or
    // mid-merge — merges are atomic under the structural-updates mutex)
    // cluster because its input, `cluster_loads`, lists only owned,
    // active clusters. Merge a few clusters away and check both the
    // snapshot and a fresh plan.
    let b = builder(4, "planner-input");
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (subject, _mem) = b.index(&built, IndexKind::EdgeRag).unwrap();
    let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
    let n_chunks = built.corpus.len() as u32;

    let mut merged = Vec::new();
    for _ in 0..2 {
        let loads = sharded.cluster_loads();
        let (g, _) = loads
            .iter()
            .flatten()
            .filter(|c| c.rows > 0)
            .map(|c| (c.global, c.load()))
            .min_by_key(|&(g, l)| (l, g))
            .unwrap();
        for id in members_of(sharded, g, n_chunks + 1) {
            sharded.remove_chunk(id).unwrap();
        }
        merged.push(g);
        sharded.verify_integrity().unwrap();
    }

    let loads = sharded.cluster_loads();
    for &g in &merged {
        assert!(
            !loads.iter().flatten().any(|c| c.global == g),
            "merged cluster {g} still in the planner's load snapshot"
        );
    }
    let plan = plan_rebalance(&loads, 8);
    for m in &plan.moves {
        assert!(
            !merged.contains(&m.cluster),
            "planner scheduled merged cluster {}: {plan:?}",
            m.cluster
        );
    }
}

#[test]
fn stale_plan_skips_merged_clusters() {
    // A plan computed before a merge may name the merged cluster; the
    // execution primitive must skip it (no resurrection, no invariant
    // damage) while the rest of the plan executes.
    let b = builder(4, "stale-plan");
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (subject, _mem) = b.index(&built, IndexKind::EdgeRag).unwrap();
    let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
    let n_chunks = built.corpus.len() as u32;

    // Worst-case skew makes the plan non-trivial.
    let globals: Vec<u32> = sharded
        .cluster_loads()
        .iter()
        .flatten()
        .map(|c| c.global)
        .collect();
    for &g in &globals {
        sharded.migrate_cluster(g, 0).unwrap();
    }
    let plan = plan_rebalance(&sharded.cluster_loads(), 4);
    assert!(!plan.moves.is_empty(), "skewed placement must plan moves");

    // Merge the first planned cluster away before the plan executes.
    let doomed = plan.moves[0].cluster;
    for id in members_of(sharded, doomed, n_chunks + 1) {
        sharded.remove_chunk(id).unwrap();
    }
    assert!(
        !sharded
            .cluster_loads()
            .iter()
            .flatten()
            .any(|c| c.global == doomed),
        "cluster {doomed} should have merged away"
    );

    for m in &plan.moves {
        let did = sharded.migrate_cluster(m.cluster, m.to).unwrap();
        if m.cluster == doomed {
            assert!(!did, "stale move executed against merged cluster {doomed}");
        }
    }
    sharded.verify_integrity().unwrap();
}
