//! Cross-language embedding contract: rust (tokenizer → PJRT-compiled
//! HLO with the Pallas kernels) must produce the same vectors python
//! (tokenizer → jax/Pallas interpret) produced for the golden texts in
//! `tests/golden/embeddings.json`. This pins the ENTIRE build-vs-serve
//! path: tokenizer parity, weight-blob loading, HLO lowering, PJRT
//! execution.

use edgerag::embedding::{Embedder, EmbedderBackend};
use edgerag::json;
use edgerag::testutil::shared_compute;

/// Golden parity needs BOTH the python-generated golden file AND the real
/// compiled artifacts executing through PJRT. Without either this test
/// skips with a note instead of failing — tracking: ROADMAP "tier-1
/// triage" (regenerate with `python/tools/gen_golden.py` + `make
/// artifacts`).
fn golden() -> Option<json::Value> {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/embeddings.json");
    if !path.exists() {
        eprintln!("skipping: {} not generated", path.display());
        return None;
    }
    if shared_compute().backend_name() != "pjrt" {
        eprintln!("skipping: compute backend is `reference`, golden parity needs PJRT");
        return None;
    }
    Some(json::parse(&std::fs::read_to_string(path).expect("golden file")).unwrap())
}

fn check(backend: EmbedderBackend, key: &str, tol: f32) {
    let Some(g) = golden() else { return };
    let texts: Vec<String> = g
        .get("texts")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|t| t.as_str().unwrap().to_string())
        .collect();
    let want: Vec<Vec<f32>> = g
        .get(key)
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|row| {
            row.as_array()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as f32)
                .collect()
        })
        .collect();

    let emb = Embedder::new(shared_compute(), backend);
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let got = emb.embed_texts(&refs).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, wrow) in want.iter().enumerate() {
        let grow = got.row(i);
        assert_eq!(grow.len(), wrow.len());
        for (j, (a, b)) in grow.iter().zip(wrow).enumerate() {
            assert!(
                (a - b).abs() < tol,
                "{key} text {i} dim {j}: rust {a} vs python {b}"
            );
        }
    }
}

#[test]
fn projection_matches_python() {
    check(EmbedderBackend::Projection, "projection", 2e-5);
}

#[test]
fn transformer_matches_python() {
    check(EmbedderBackend::Transformer, "encoder", 5e-5);
}
