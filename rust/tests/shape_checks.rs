//! Reproduction *shape* checks: the qualitative claims of the paper's
//! figures, asserted as tests. These run on a small profile with a
//! proportionally shrunk memory budget, so the full suite stays fast while
//! still exercising the exact phenomena the figure benches measure at
//! scale.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::eval::harness::{run_workload, RunOptions};
use edgerag::testutil::shared_compute;

/// A device whose memory is too small for the tiny dataset's embeddings —
/// the scaled analogue of nq/hotpotqa/fever on the Jetson.
fn tight_device() -> DeviceProfile {
    DeviceProfile {
        // tiny = 512 chunks × 1 KiB = 512 KiB of embeddings; give the
        // device 256 KiB + LLM share so the IVF/Flat baselines thrash.
        mem_total_bytes: 640 << 10,
        llm_weight_bytes: 384 << 10,
        ..DeviceProfile::jetson_orin_nano()
    }
}

fn builder(device: DeviceProfile) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), device);
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    // Proportionally larger than the real device's ~8% because tiny's
    // clusters (~64 KiB) are huge relative to its 640 KiB budget; the
    // cache must hold at least a few clusters for its policy to act.
    b.retrieval.cache_capacity_bytes = 192 << 10;
    b
}

fn opts(n: usize) -> RunOptions {
    RunOptions {
        query_limit: Some(n),
        warmup: 16, // steady state: exclude cold-start faults
        ..Default::default()
    }
}

#[test]
fn fig3_shape_baselines_thrash_when_db_exceeds_memory() {
    let b = builder(tight_device());
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let ivf = run_workload(&b, &d, IndexKind::Ivf, &opts(60)).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts(60)).unwrap();
    assert!(ivf.thrash_faults > 0, "IVF must thrash under pressure");
    assert_eq!(edge.thrash_faults, 0, "EdgeRAG must stay within memory");
    assert!(
        edge.ttft_mean < ivf.ttft_mean,
        "edge {} !< ivf {}",
        edge.ttft_mean,
        ivf.ttft_mean
    );
}

#[test]
fn fig3_shape_no_thrash_when_db_fits() {
    // Small datasets (scidocs/fiqa analogue): IVF is fine and beats
    // online generation — exactly the paper's §6.3.4 observation.
    let b = builder(DeviceProfile::jetson_orin_nano());
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let ivf = run_workload(&b, &d, IndexKind::Ivf, &opts(60)).unwrap();
    let gen = run_workload(&b, &d, IndexKind::IvfGen, &opts(60)).unwrap();
    assert_eq!(ivf.thrash_faults, 0);
    assert!(
        ivf.retrieval_mean < gen.retrieval_mean,
        "in-memory IVF must beat pure online generation on small data"
    );
}

#[test]
fn fig12_shape_each_optimization_reduces_tail() {
    let b = builder(tight_device());
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let n = 80;
    let ivf = run_workload(&b, &d, IndexKind::Ivf, &opts(n)).unwrap();
    let gen = run_workload(&b, &d, IndexKind::IvfGen, &opts(n)).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts(n)).unwrap();
    // +gen eliminates thrash-driven tails.
    assert!(
        gen.retrieval_p95 < ivf.retrieval_p95,
        "gen p95 {} !< ivf p95 {}",
        gen.retrieval_p95,
        ivf.retrieval_p95
    );
    // EdgeRAG (storage + cache) improves the mean further.
    assert!(
        edge.retrieval_mean < gen.retrieval_mean,
        "edge mean {} !< gen mean {}",
        edge.retrieval_mean,
        gen.retrieval_mean
    );
    // And its cache actually hits.
    assert!(edge.cache.unwrap().hits > 0);
}

#[test]
fn fig7_shape_threshold_tradeoff() {
    // Threshold 0 caches everything (max hit rate); a huge threshold
    // caches nothing (zero hit rate) — the Fig. 7 extremes.
    let b = builder(tight_device());
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let all = run_workload(
        &b,
        &d,
        IndexKind::EdgeRag,
        &RunOptions {
            pin_threshold_ms: Some(0.0),
            ..opts(80)
        },
    )
    .unwrap();
    let none = run_workload(
        &b,
        &d,
        IndexKind::EdgeRag,
        &RunOptions {
            pin_threshold_ms: Some(1e9),
            ..opts(80)
        },
    )
    .unwrap();
    let hr_all = all.cache.unwrap().hit_rate();
    let hr_none = none.cache.unwrap().hit_rate();
    assert!(hr_all > 0.05, "threshold-0 hit rate {hr_all}");
    assert_eq!(hr_none, 0.0);
    assert!(
        all.retrieval_mean < none.retrieval_mean,
        "caching must help on a reuse-heavy workload"
    );
}

#[test]
fn fig5_shape_cluster_costs_are_tail_heavy() {
    let mut b = builder(DeviceProfile::jetson_orin_nano());
    // Topic-mean clustering preserves the corpus's natural (tail-heavy)
    // cluster sizes — the configuration the large profiles use.
    b.options.topic_init = Some(true);
    let mut p = DatasetProfile::tiny();
    p.n_chunks = 2048;
    p.n_topics = 64;
    p.cluster_sigma = 1.2;
    let d = b.build_dataset(&p).unwrap();
    let set = d.cluster_set(&b.device);
    let mut costs: Vec<f64> = set
        .clusters
        .iter()
        .filter(|c| !c.is_empty())
        .map(|c| c.gen_cost.as_millis_f64())
        .collect();
    costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = costs[costs.len() / 2];
    let max = *costs.last().unwrap();
    assert!(
        max / median > 4.0,
        "cluster gen-cost tail too light: max/median {}",
        max / median
    );
}

#[test]
fn headline_shape_quality_within_5_percent_of_flat() {
    let b = builder(DeviceProfile::jetson_orin_nano());
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let flat = run_workload(&b, &d, IndexKind::Flat, &opts(60)).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts(60)).unwrap();
    let recall_drop = (flat.quality.recall - edge.quality.recall) / flat.quality.recall;
    let gen_drop = (flat.gen_score - edge.gen_score) / flat.gen_score;
    assert!(recall_drop < 0.10, "recall drop {recall_drop}");
    assert!(gen_drop < 0.10, "gen-score drop {gen_drop}");
}

#[test]
fn cache_overhead_stays_small() {
    // Paper: caching uses ≈7% of system memory on top of the pruned
    // index. Checked against the real device profile with the default
    // cache capacity (4 MiB of 48 MiB ≈ 8%).
    let mut b = builder(DeviceProfile::jetson_orin_nano());
    b.retrieval.cache_capacity_bytes =
        edgerag::config::RetrievalConfig::default().cache_capacity_bytes;
    let d = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts(80)).unwrap();
    let frac = edge.cache_used_bytes as f64 / b.device.mem_total_bytes as f64;
    assert!(frac <= 0.10, "cache uses {:.1}% of memory", frac * 100.0);
}
