//! Crash-consistency tests for the removal path and the migrate-then-merge
//! path (`ShardedEdgeIndex::remove_chunk` → cross-shard merge routing).
//!
//! An injectable failing blob store ([`BlobStore::inject_put_failures`]
//! / [`inject_remove_failures`]) proves the blob-first ordering of every
//! structural op on this path: a blob fault at any fallible step leaves
//! **both shards consistent** (`verify_integrity` passes, the old state
//! keeps serving, no chunk is lost) and the op **retries cleanly** —
//! a faulted removal by calling `remove_chunk` again, a faulted merge
//! through [`ShardedEdgeIndex::merge_drained`].
//!
//! Five fault points are exercised:
//! 1. the victim-blob `put` of a **cross-shard** merge — fails after the
//!    migrate half, leaving a plain (fully consistent) migration;
//! 2. the drained cluster's blob `remove` inside the triggering removal —
//!    the removal's first fallible write, so the whole removal (and the
//!    merge behind it) aborts with the placement untouched;
//! 3. the victim-blob `put` of a **same-shard** merge — fails before any
//!    membership mutation;
//! 4. the post-removal blob `put` of a plain (non-draining) removal —
//!    runs before membership mutates, so the fault aborts the removal
//!    atomically instead of stranding a stale blob;
//! 5. (absence) a drain-crossing removal must **not** re-put the blob the
//!    merge immediately deletes — an armed put fault on the source shard
//!    stays unconsumed while the composed remove+merge completes.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::index::updates::MERGE_THRESHOLD;
use edgerag::index::{ShardedEdgeIndex, VectorIndex};
use edgerag::testutil::shared_compute;

fn builder(shards: usize, tag: &str, store_slo_fraction: f64) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-mfault-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    // store_slo_fraction = 0 ⇒ store_limit = 0 ⇒ every non-empty cluster
    // keeps a blob, so the merge's victim-blob `put` always runs.
    b.retrieval.store_slo_fraction = store_slo_fraction;
    b
}

struct Fx {
    b: SystemBuilder,
    built: edgerag::coordinator::builder::BuiltDataset,
    sharded_box: Box<dyn VectorIndex>,
    _mem: edgerag::index::SharedMemory,
    n_chunks: u32,
}

impl Fx {
    fn sharded(&self) -> &ShardedEdgeIndex {
        self.sharded_box
            .as_any()
            .downcast_ref::<ShardedEdgeIndex>()
            .unwrap()
    }

    /// Embed a chunk's own text — its top hit must be itself.
    fn self_query(&self, chunk: u32) -> Vec<f32> {
        self.b
            .embedder()
            .embed_one(&self.built.corpus.chunks[chunk as usize].text)
            .unwrap()
    }
}

fn fixture(tag: &str, store_slo_fraction: f64) -> Fx {
    let b = builder(2, tag, store_slo_fraction);
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (sharded_box, _mem) = b.index(&built, IndexKind::EdgeRag).unwrap();
    let n_chunks = built.corpus.len() as u32;
    Fx {
        b,
        built,
        sharded_box,
        _mem,
        n_chunks,
    }
}

/// Pick a drainable cluster, arrange its merge victim on the requested
/// side (same shard or cross-shard, by migrating the drained cluster —
/// victim selection is placement-independent, so the victim does not
/// move), then drain it to exactly `MERGE_THRESHOLD` members. Returns
/// `(global, victim, survivor, trigger)`: removing `trigger` fires the
/// merge and `survivor` must land in `victim`.
fn stage_drain(fx: &Fx, cross_shard: bool) -> (u32, u32, u32, u32) {
    let sharded = fx.sharded();
    let loads = sharded.cluster_loads();
    let (g, _) = loads
        .iter()
        .flatten()
        .filter(|c| c.rows > MERGE_THRESHOLD as u64)
        .map(|c| (c.global, c.rows))
        .min_by_key(|&(g, r)| (r, g))
        .expect("a drainable cluster exists");
    let victim = sharded
        .merge_victim(g)
        .unwrap()
        .expect("more than one active cluster");
    let vs = sharded.shard_of(victim);
    let want = if cross_shard {
        (vs + 1) % sharded.shards()
    } else {
        vs
    };
    if sharded.shard_of(g) != want {
        assert!(sharded.migrate_cluster(g, want).unwrap());
    }
    assert_eq!(
        sharded.merge_victim(g).unwrap(),
        Some(victim),
        "victim selection must be placement-independent"
    );

    let mut members: Vec<u32> = (0..fx.n_chunks)
        .filter(|&id| sharded.cluster_of(id) == Some(g))
        .collect();
    while members.len() > MERGE_THRESHOLD {
        let id = members.pop().unwrap();
        assert!(sharded.remove_chunk(id).unwrap());
    }
    let trigger = members.pop().unwrap();
    let survivor = members.pop().unwrap();
    sharded.verify_integrity().unwrap();
    (g, victim, survivor, trigger)
}

#[test]
fn victim_put_fault_mid_cross_shard_merge_is_recoverable() {
    let fx = fixture("xput", 0.0);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, true);
    let src = sharded.shard_of(g);
    let vs = sharded.shard_of(victim);
    assert_ne!(src, vs, "staged a cross-shard merge");

    // The merge's only `put` on the victim shard is the combined victim
    // blob — fail it. (The triggering removal drops the drained blob on
    // the *source* shard — a `remove`, not a `put` — so nothing before
    // the merge can consume this charge.)
    sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(1));
    let err = sharded.remove_chunk(trigger);
    assert!(err.is_err(), "injected put fault must surface");

    // The chunk is removed; the merge did not complete: the drained
    // cluster was migrated to the victim's shard (the composed op's
    // migrate half) but still owns its survivor, and every invariant
    // holds on both shards.
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None, "removal took effect");
    assert_eq!(
        sharded.cluster_of(survivor),
        Some(g),
        "failed merge must leave the drained cluster serving its survivor"
    );
    assert_eq!(
        sharded.shard_of(g),
        vs,
        "the migrate half completed before the fault"
    );

    // Old state keeps serving: the survivor is still retrievable.
    let out = sharded.search(&fx.self_query(survivor), 3).unwrap();
    assert_eq!(out.hits[0].0, survivor, "hits: {:?}", out.hits);

    // Retry (now a same-shard merge) completes cleanly.
    assert!(sharded.merge_drained(g).unwrap());
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    let out = sharded.search(&fx.self_query(survivor), 3).unwrap();
    assert_eq!(out.hits[0].0, survivor, "post-retry hits: {:?}", out.hits);
    let merges: u64 = sharded.shard_stats().iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1, "exactly the retried merge completed");
}

#[test]
fn source_remove_fault_aborts_removal_and_merge_untouched() {
    let fx = fixture("xremove", 0.0);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, true);
    let src = sharded.shard_of(g);
    let vs = sharded.shard_of(victim);
    assert_ne!(src, vs, "staged a cross-shard merge");

    // Fail the drained cluster's blob drop. Removal is blob-first, so
    // this is the removal's *own* first fallible write — before any
    // membership mutation — and the whole composed op (removal + merge)
    // must abort with the placement fully untouched.
    sharded.with_shard(src, |e| e.blob_store().unwrap().inject_remove_failures(1));
    let err = sharded.remove_chunk(trigger);
    assert!(err.is_err(), "injected remove fault must surface");

    sharded.verify_integrity().unwrap();
    assert_eq!(
        sharded.cluster_of(trigger),
        Some(g),
        "blob-first removal aborts atomically — the chunk is still routed"
    );
    assert_eq!(sharded.cluster_of(survivor), Some(g));
    assert_eq!(
        sharded.shard_of(g),
        src,
        "nothing may migrate when the op aborts at its first fallible write"
    );

    // The aborted removal keeps serving: the trigger is still retrievable.
    let out = sharded.search(&fx.self_query(trigger), 3).unwrap();
    assert_eq!(out.hits[0].0, trigger, "hits: {:?}", out.hits);

    // Retry the removal itself; it re-runs the blob drop and then the
    // full cross-shard merge composition inline.
    assert!(sharded.remove_chunk(trigger).unwrap());
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None);
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    assert_eq!(sharded.shard_of(g), vs, "retried op migrated the drained cluster");
    let stats = sharded.shard_stats();
    let merges: u64 = stats.iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1);
    assert_eq!(stats[vs].migrated_in, 1, "the retry's migrate half is accounted");
}

#[test]
fn victim_put_fault_mid_local_merge_leaves_membership_untouched() {
    // Same-shard merge: a light store limit keeps the *drained* cluster
    // below the storage threshold (the triggering removal then performs
    // no blob operation at all — a drain-crossing removal never puts,
    // and there is no blob to drop) while normal clusters stay stored,
    // so the armed fault fires exactly at the merge's victim `put`.
    let fx = fixture("localput", 0.05);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, false);
    let vs = sharded.shard_of(victim);
    assert_eq!(sharded.shard_of(g), vs, "staged a same-shard merge");
    let victim_stored = sharded.with_shard(vs, |e| e.stored_clusters() > 0);
    assert!(
        victim_stored,
        "fixture needs stored clusters for the fault to be reachable"
    );

    sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(1));
    let res = sharded.remove_chunk(trigger);
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None, "removal took effect");

    if res.is_err() {
        // The fault fired inside the merge: membership must be
        // untouched and the retry must complete it.
        assert_eq!(sharded.cluster_of(survivor), Some(g));
        assert!(sharded.merge_drained(g).unwrap());
    } else {
        // The victim's post-merge state did not need a stored blob (its
        // gen cost sits below the limit), so no put ran and the merge
        // completed first try — consume the unused charge.
        sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(0));
    }
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    let merges: u64 = sharded.shard_stats().iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1);
}

#[test]
fn removal_put_fault_leaves_membership_untouched() {
    // A plain (non-draining) removal of a stored cluster's member must
    // re-store the post-removal blob *before* mutating membership: a
    // put fault aborts the removal atomically instead of leaving the
    // membership updated with a stale blob still serving the removed
    // chunk's row.
    let fx = fixture("remput", 0.0);
    let sharded = fx.sharded();
    let loads = sharded.cluster_loads();
    let (g, _) = loads
        .iter()
        .flatten()
        .filter(|c| c.rows > MERGE_THRESHOLD as u64 + 1)
        .map(|c| (c.global, c.rows))
        .min_by_key(|&(g, r)| (r, g))
        .expect("a cluster that survives one removal exists");
    let id = (0..fx.n_chunks)
        .find(|&id| sharded.cluster_of(id) == Some(g))
        .expect("cluster has members");
    let s = sharded.shard_of(g);

    sharded.with_shard(s, |e| e.blob_store().unwrap().inject_put_failures(1));
    let err = sharded.remove_chunk(id);
    assert!(err.is_err(), "injected put fault must surface");

    sharded.verify_integrity().unwrap();
    assert_eq!(
        sharded.cluster_of(id),
        Some(g),
        "blob-first removal aborts atomically — the chunk is still routed"
    );
    let out = sharded.search(&fx.self_query(id), 3).unwrap();
    assert_eq!(out.hits[0].0, id, "aborted removal keeps serving: {:?}", out.hits);

    // Retry completes: charge consumed, put succeeds, membership rewires.
    assert!(sharded.remove_chunk(id).unwrap());
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(id), None);
    let out = sharded.search(&fx.self_query(id), 3).unwrap();
    assert_ne!(out.hits[0].0, id, "removed chunk no longer served");
}

#[test]
fn drain_crossing_removal_skips_blob_reput() {
    // The removal that drains a cluster below MERGE_THRESHOLD must not
    // re-put the drained blob the merge immediately deletes. Proof by
    // armed fault: with a put fault armed on the *source* shard, the
    // composed remove + cross-shard merge completes anyway — the
    // removal's only source-side blob op is a `remove`, and the merge's
    // only `put` lands on the victim shard. (The retired refresh-based
    // removal re-put the drained blob and tripped this charge.)
    let fx = fixture("noreput", 0.0);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, true);
    let src = sharded.shard_of(g);
    let vs = sharded.shard_of(victim);
    assert_ne!(src, vs, "staged a cross-shard merge");

    sharded.with_shard(src, |e| e.blob_store().unwrap().inject_put_failures(1));
    assert!(
        sharded.remove_chunk(trigger).unwrap(),
        "drain-crossing removal performs no source-side put"
    );
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None);
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    assert_eq!(sharded.shard_of(g), vs, "merge migrated the drained cluster");
    let merges: u64 = sharded.shard_stats().iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1);

    // The charge must still be armed — disarm it so teardown is clean.
    sharded.with_shard(src, |e| e.blob_store().unwrap().inject_put_failures(0));
}
