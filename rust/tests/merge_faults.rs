//! Crash-consistency tests for the migrate-then-merge path
//! (`ShardedEdgeIndex::remove_chunk` → cross-shard merge routing).
//!
//! An injectable failing blob store ([`BlobStore::inject_put_failures`]
//! / [`inject_remove_failures`]) proves the composed structural op's
//! blob-first ordering: a blob fault at any fallible step leaves **both
//! shards consistent** (`verify_integrity` passes, the old state keeps
//! serving, no chunk is lost) and the merge **retries cleanly** through
//! [`ShardedEdgeIndex::merge_drained`].
//!
//! Three fault points are exercised:
//! 1. the victim-blob `put` of a **cross-shard** merge — fails after the
//!    migrate half, leaving a plain (fully consistent) migration;
//! 2. the source-blob `remove` of a cross-shard merge — fails before
//!    anything moved, leaving the pre-merge state untouched;
//! 3. the victim-blob `put` of a **same-shard** merge — fails before any
//!    membership mutation.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::index::updates::MERGE_THRESHOLD;
use edgerag::index::{ShardedEdgeIndex, VectorIndex};
use edgerag::testutil::shared_compute;

fn builder(shards: usize, tag: &str, store_slo_fraction: f64) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-mfault-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    // store_slo_fraction = 0 ⇒ store_limit = 0 ⇒ every non-empty cluster
    // keeps a blob, so the merge's victim-blob `put` always runs.
    b.retrieval.store_slo_fraction = store_slo_fraction;
    b
}

struct Fx {
    b: SystemBuilder,
    built: edgerag::coordinator::builder::BuiltDataset,
    sharded_box: Box<dyn VectorIndex>,
    _mem: edgerag::index::SharedMemory,
    n_chunks: u32,
}

impl Fx {
    fn sharded(&self) -> &ShardedEdgeIndex {
        self.sharded_box
            .as_any()
            .downcast_ref::<ShardedEdgeIndex>()
            .unwrap()
    }

    /// Embed a chunk's own text — its top hit must be itself.
    fn self_query(&self, chunk: u32) -> Vec<f32> {
        self.b
            .embedder()
            .embed_one(&self.built.corpus.chunks[chunk as usize].text)
            .unwrap()
    }
}

fn fixture(tag: &str, store_slo_fraction: f64) -> Fx {
    let b = builder(2, tag, store_slo_fraction);
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (sharded_box, _mem) = b.index(&built, IndexKind::EdgeRag).unwrap();
    let n_chunks = built.corpus.len() as u32;
    Fx {
        b,
        built,
        sharded_box,
        _mem,
        n_chunks,
    }
}

/// Pick a drainable cluster, arrange its merge victim on the requested
/// side (same shard or cross-shard, by migrating the drained cluster —
/// victim selection is placement-independent, so the victim does not
/// move), then drain it to exactly `MERGE_THRESHOLD` members. Returns
/// `(global, victim, survivor, trigger)`: removing `trigger` fires the
/// merge and `survivor` must land in `victim`.
fn stage_drain(fx: &Fx, cross_shard: bool) -> (u32, u32, u32, u32) {
    let sharded = fx.sharded();
    let loads = sharded.cluster_loads();
    let (g, _) = loads
        .iter()
        .flatten()
        .filter(|c| c.rows > MERGE_THRESHOLD as u64)
        .map(|c| (c.global, c.rows))
        .min_by_key(|&(g, r)| (r, g))
        .expect("a drainable cluster exists");
    let victim = sharded
        .merge_victim(g)
        .unwrap()
        .expect("more than one active cluster");
    let vs = sharded.shard_of(victim);
    let want = if cross_shard {
        (vs + 1) % sharded.shards()
    } else {
        vs
    };
    if sharded.shard_of(g) != want {
        assert!(sharded.migrate_cluster(g, want).unwrap());
    }
    assert_eq!(
        sharded.merge_victim(g).unwrap(),
        Some(victim),
        "victim selection must be placement-independent"
    );

    let mut members: Vec<u32> = (0..fx.n_chunks)
        .filter(|&id| sharded.cluster_of(id) == Some(g))
        .collect();
    while members.len() > MERGE_THRESHOLD {
        let id = members.pop().unwrap();
        assert!(sharded.remove_chunk(id).unwrap());
    }
    let trigger = members.pop().unwrap();
    let survivor = members.pop().unwrap();
    sharded.verify_integrity().unwrap();
    (g, victim, survivor, trigger)
}

#[test]
fn victim_put_fault_mid_cross_shard_merge_is_recoverable() {
    let fx = fixture("xput", 0.0);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, true);
    let src = sharded.shard_of(g);
    let vs = sharded.shard_of(victim);
    assert_ne!(src, vs, "staged a cross-shard merge");

    // The merge's only `put` on the victim shard is the combined victim
    // blob — fail it. (The triggering removal's own refresh `put` runs
    // on the source shard and does not consume this charge.)
    sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(1));
    let err = sharded.remove_chunk(trigger);
    assert!(err.is_err(), "injected put fault must surface");

    // The chunk is removed; the merge did not complete: the drained
    // cluster was migrated to the victim's shard (the composed op's
    // migrate half) but still owns its survivor, and every invariant
    // holds on both shards.
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None, "removal took effect");
    assert_eq!(
        sharded.cluster_of(survivor),
        Some(g),
        "failed merge must leave the drained cluster serving its survivor"
    );
    assert_eq!(
        sharded.shard_of(g),
        vs,
        "the migrate half completed before the fault"
    );

    // Old state keeps serving: the survivor is still retrievable.
    let out = sharded.search(&fx.self_query(survivor), 3).unwrap();
    assert_eq!(out.hits[0].0, survivor, "hits: {:?}", out.hits);

    // Retry (now a same-shard merge) completes cleanly.
    assert!(sharded.merge_drained(g).unwrap());
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    let out = sharded.search(&fx.self_query(survivor), 3).unwrap();
    assert_eq!(out.hits[0].0, survivor, "post-retry hits: {:?}", out.hits);
    let merges: u64 = sharded.shard_stats().iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1, "exactly the retried merge completed");
}

#[test]
fn source_remove_fault_aborts_cross_shard_merge_untouched() {
    let fx = fixture("xremove", 0.0);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, true);
    let src = sharded.shard_of(g);
    let vs = sharded.shard_of(victim);
    assert_ne!(src, vs, "staged a cross-shard merge");

    // Fail the drained cluster's blob drop — the first mutating step of
    // the composed op. Everything before it is read-only, so the abort
    // must leave the placement fully untouched.
    sharded.with_shard(src, |e| e.blob_store().unwrap().inject_remove_failures(1));
    let err = sharded.remove_chunk(trigger);
    assert!(err.is_err(), "injected remove fault must surface");

    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None, "removal took effect");
    assert_eq!(sharded.cluster_of(survivor), Some(g));
    assert_eq!(
        sharded.shard_of(g),
        src,
        "nothing may migrate when the op aborts at its first fallible write"
    );

    // Retry runs the full cross-shard composition.
    assert!(sharded.merge_drained(g).unwrap());
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    assert_eq!(sharded.shard_of(g), vs, "retried merge migrated the drained cluster");
    let stats = sharded.shard_stats();
    let merges: u64 = stats.iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1);
    assert_eq!(stats[vs].migrated_in, 1, "the retry's migrate half is accounted");
}

#[test]
fn victim_put_fault_mid_local_merge_leaves_membership_untouched() {
    // Same-shard merge: a light store limit keeps the *drained* cluster
    // below the storage threshold (its refresh on the triggering removal
    // must not consume the injected charge) while normal clusters stay
    // stored, so the armed fault fires exactly at the merge's victim
    // `put`.
    let fx = fixture("localput", 0.05);
    let sharded = fx.sharded();
    let (g, victim, survivor, trigger) = stage_drain(&fx, false);
    let vs = sharded.shard_of(victim);
    assert_eq!(sharded.shard_of(g), vs, "staged a same-shard merge");
    let victim_stored = sharded.with_shard(vs, |e| e.stored_clusters() > 0);
    assert!(
        victim_stored,
        "fixture needs stored clusters for the fault to be reachable"
    );

    sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(1));
    let res = sharded.remove_chunk(trigger);
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(trigger), None, "removal took effect");

    if res.is_err() {
        // The fault fired inside the merge: membership must be
        // untouched and the retry must complete it.
        assert_eq!(sharded.cluster_of(survivor), Some(g));
        assert!(sharded.merge_drained(g).unwrap());
    } else {
        // The victim's post-merge state did not need a stored blob (its
        // gen cost sits below the limit), so no put ran and the merge
        // completed first try — consume the unused charge.
        sharded.with_shard(vs, |e| e.blob_store().unwrap().inject_put_failures(0));
    }
    sharded.verify_integrity().unwrap();
    assert_eq!(sharded.cluster_of(survivor), Some(victim));
    let merges: u64 = sharded.shard_stats().iter().map(|s| s.merges).sum();
    assert_eq!(merges, 1);
}
