//! Cross-language tokenizer contract: rust must produce exactly the ids in
//! `tests/golden/tokenizer.json`, which `python/tests/test_tokenizer.py`
//! validates against the python implementation. Any drift between the two
//! sides breaks embedding equality between build time and serving time.

use edgerag::embedding::tokenizer;
use edgerag::json;

#[test]
fn matches_python_golden_vectors() {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/tokenizer.json");
    if !path.exists() {
        // Tracking: ROADMAP "tier-1 triage" — golden files are generated
        // by `python/tools/gen_golden.py`; skip (not fail) when absent so
        // the suite runs in environments without the python toolchain.
        eprintln!("skipping: {} not generated", path.display());
        return;
    }
    let text = std::fs::read_to_string(path).expect("golden file");
    let cases = json::parse(&text).unwrap();
    let cases = cases.as_array().expect("array");
    assert!(cases.len() >= 8);
    for case in cases {
        let text = case.get("text").unwrap().as_str().unwrap();
        let want: Vec<i32> = case
            .get("ids")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(tokenizer::token_ids(text), want, "text: {text:?}");
    }
}

#[test]
fn randomized_invariants() {
    // Property-style sweep (deterministic Rng substitutes for proptest,
    // which is unavailable offline): ids in range, features consistent.
    let mut rng = edgerag::data::Rng::new(99);
    for _ in 0..500 {
        let len = rng.below(120);
        let text: String = (0..len)
            .map(|_| {
                let c = rng.below(90) as u8 + 33;
                c as char
            })
            .collect();
        let ids = tokenizer::token_ids(&text);
        for &id in &ids {
            assert!((2..tokenizer::VOCAB as i32).contains(&id));
        }
        let f = tokenizer::features(&text);
        assert_eq!(f.iter().sum::<f32>() as usize, ids.len());
        let (seq, mask) = tokenizer::sequence(&text, 16);
        assert_eq!(seq.len(), 16);
        assert_eq!(
            mask.iter().sum::<f32>() as usize,
            (ids.len() + 1).min(16)
        );
    }
}
