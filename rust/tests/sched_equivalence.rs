//! Cross-query batch scheduler: batched-vs-unbatched equivalence and the
//! scheduler's operational properties at the system level.
//!
//! The acceptance property: with batching enabled, search results
//! (top-k ids, f32 scores, probed clusters, materialization events) and
//! cache admissions are **bit-identical** to the unbatched path for the
//! same request set — for both the single [`EdgeIndex`] and the sharded
//! index (`EDGERAG_TEST_SHARDS` pins the shard counts; CI runs an
//! explicit `--shards 4` pass).
//!
//! `EDGERAG_TEST_TRACE=1` re-runs the bit-equality legs with the
//! tracing plane armed and every handled query carrying an active
//! trace, proving the span record sites are purely observational (CI
//! runs this leg explicitly).
//!
//! `EDGERAG_TEST_DEADLINE=1` re-runs them with a generous per-query
//! deadline armed — deadline stamping, earliest-rider batch close, and
//! the dequeue shed gates are all live but never fire, proving the
//! deadline plane does not perturb successful results (CI runs this leg
//! explicitly too).

use std::sync::Arc;
use std::time::{Duration, Instant};

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::coordinator::Engine;
use edgerag::sched::{BatchScheduler, SchedConfig};
use edgerag::testutil::shared_compute;
use edgerag::trace::Tracer;

/// The `EDGERAG_TEST_TRACE=1` tracing plane: arming it turns every span
/// record site live, and [`traced`] gives each handled query an active
/// thread-local trace — the bit-equality assertions must hold anyway.
fn test_tracer() -> Option<Arc<Tracer>> {
    match std::env::var("EDGERAG_TEST_TRACE") {
        Ok(v) if v == "1" => Some(Tracer::new(0)),
        _ => None,
    }
}

/// Run one query under an active trace when the trace leg is on.
fn traced<T>(tracer: &Option<Arc<Tracer>>, f: impl FnOnce() -> T) -> T {
    match tracer {
        Some(tr) => {
            let guard = tr.begin("query", Instant::now());
            let out = f();
            let _ = guard.finish();
            out
        }
        None => f(),
    }
}

fn builder(shards: usize, tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    // Per-test blob-store root: tests in this binary run in parallel and
    // must not clear each other's stores.
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-sched-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    b
}

/// Bit-exact assertions hold on the reference backend by construction
/// (per-row kernels). Compiled PJRT graphs are lowered separately per
/// batch shape and may round differently in the low bits — the same
/// reason golden-parity tests are artifact-gated (see
/// `rust/vendor/README.md` §"Tier-1 quarantine").
fn reference_backend() -> bool {
    if shared_compute().backend_name() == "pjrt" {
        eprintln!(
            "skipping: batched bit-equivalence is asserted on the reference backend; \
             compiled kernels may round differently across batch shapes"
        );
        return false;
    }
    true
}

/// Shard counts under test: `EDGERAG_TEST_SHARDS=N` pins a single count
/// (the CI `--shards 4` pass); default covers both the plain EdgeIndex
/// and a sharded index.
fn shard_counts() -> Vec<usize> {
    match std::env::var("EDGERAG_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("EDGERAG_TEST_SHARDS must be an integer")],
        Err(_) => vec![1, 4],
    }
}

fn build_engine(shards: usize, tag: &str) -> (SystemBuilder, Arc<Engine>, Vec<String>) {
    let b = builder(shards, tag);
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());
    // Pin the caching threshold so admissions are policy-deterministic:
    // under concurrency the adaptive controller observes commits in a
    // nondeterministic order, which could legitimately diverge the gate.
    engine.index_mut().pin_threshold(0.0);
    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(24)
        .map(|q| q.text.clone())
        .collect();
    (b, engine, queries)
}

/// Query deadline under test: `EDGERAG_TEST_DEADLINE=1` arms a generous
/// (two-minute) per-query deadline — the deadline plumbing is live on
/// every query (stamped at admission, riders close batches, dequeue
/// shed gates run) but never fires, so the bit-equality assertions must
/// hold unchanged. CI runs this leg explicitly.
fn test_deadline_us() -> u64 {
    match std::env::var("EDGERAG_TEST_DEADLINE") {
        Ok(v) if v == "1" => 120_000_000,
        _ => 0,
    }
}

fn sched_cfg(bypass: bool) -> SchedConfig {
    SchedConfig {
        batch_window_us: 300,
        max_inflight: 0,
        deadline_us: test_deadline_us(),
        bypass,
    }
}

#[test]
fn forced_batching_is_bit_identical_sequentially() {
    if !reference_backend() {
        return;
    }
    // Sequential + bypass disabled: every query runs through the fused
    // proj/sim kernels alone (padded batches), which must reproduce the
    // unbatched path bit for bit — hits, scores, probes, events, modeled
    // latency, and the admitted cache set.
    let tracer = test_tracer();
    for shards in shard_counts() {
        let (_b1, unbatched, queries) = build_engine(shards, &format!("seq-u{shards}"));
        let (_b2, batched_engine, _) = build_engine(shards, &format!("seq-b{shards}"));
        let sched = BatchScheduler::new(batched_engine.clone(), sched_cfg(false));

        for (i, q) in queries.iter().enumerate() {
            let a = traced(&tracer, || unbatched.handle(q)).unwrap();
            let b = traced(&tracer, || sched.handle(q)).unwrap();
            assert_eq!(a.hits, b.hits, "shards={shards} query {i} hits");
            assert_eq!(a.retrieval, b.retrieval, "shards={shards} query {i} retrieval");
            assert_eq!(a.ttft, b.ttft, "shards={shards} query {i} ttft");
            assert_eq!(
                a.events.generated, b.events.generated,
                "shards={shards} query {i} generated"
            );
            assert_eq!(
                a.events.loaded, b.events.loaded,
                "shards={shards} query {i} loaded"
            );
            assert_eq!(
                a.events.cache_hits, b.events.cache_hits,
                "shards={shards} query {i} cache hits"
            );
        }

        // Identical cache admissions: same resident clusters, same
        // insertion counters.
        let (iu, ib) = (unbatched.index(), batched_engine.index());
        assert_eq!(
            iu.cached_clusters(),
            ib.cached_clusters(),
            "shards={shards} admitted sets diverged"
        );
        let (su, sb) = (iu.cache_stats().unwrap(), ib.cache_stats().unwrap());
        assert_eq!(su.insertions, sb.insertions, "shards={shards}");
        assert_eq!(su.hits, sb.hits, "shards={shards}");
        assert_eq!(su.misses, sb.misses, "shards={shards}");

        let stats = sched.stats();
        assert_eq!(stats.bypassed, 0, "bypass was disabled");
        assert!(stats.embed.batches > 0, "queries went through the stage");
    }
}

#[test]
fn concurrent_batched_load_matches_serial_results() {
    if !reference_backend() {
        return;
    }
    let tracer = test_tracer();
    for shards in shard_counts() {
        let (_b1, serial_engine, queries) = build_engine(shards, &format!("conc-s{shards}"));
        let serial: Vec<Vec<(u32, f32)>> = queries
            .iter()
            .map(|q| traced(&tracer, || serial_engine.handle(q)).unwrap().hits)
            .collect();

        let (_b2, engine, _) = build_engine(shards, &format!("conc-b{shards}"));
        let sched = BatchScheduler::new(engine.clone(), sched_cfg(false));
        let passes = 3;
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let sched = &sched;
                let queries = &queries;
                let serial = &serial;
                let tracer = &tracer;
                scope.spawn(move || {
                    for round in 0..passes {
                        for (i, q) in queries.iter().enumerate() {
                            let out = traced(tracer, || sched.handle(q)).unwrap();
                            assert_eq!(
                                out.hits, serial[i],
                                "shards={shards} round {round} query {i}"
                            );
                        }
                    }
                });
            }
        });

        // The admitted cache set converges to the serial run's set (every
        // probed, generated cluster is admitted at threshold 0).
        assert_eq!(
            serial_engine.index().cached_clusters(),
            engine.index().cached_clusters(),
            "shards={shards}"
        );

        // Under 8-way concurrency the stages must have actually fused
        // work: strictly fewer batches than items.
        let s = sched.stats();
        assert_eq!(s.submitted, 8 * passes as u64 * queries.len() as u64);
        assert!(
            s.probe.batches < s.probe.batched_items,
            "shards={shards}: no cross-query coalescing happened: {s:?}"
        );
        assert!(s.probe.occupancy() > 1.0, "shards={shards}: {s:?}");
    }
}

#[test]
fn live_generation_batches_cluster_reembedding() {
    if !reference_backend() {
        return;
    }
    // EmbedSource::Live + batching: on-demand cluster re-embedding flows
    // through the shared embed stage, and results still match the
    // inline-generation engine exactly.
    let mut b_live = builder(1, "live-batched");
    b_live.options.prebuilt_generation = false;
    b_live.retrieval.batching = true;
    let built = b_live.build_dataset(&DatasetProfile::tiny()).unwrap();
    let engine = Arc::new(b_live.pipeline(&built, IndexKind::EdgeRag).unwrap());
    engine.index_mut().pin_threshold(0.0);
    let sched = BatchScheduler::new(engine.clone(), sched_cfg(false));

    let (_bu, unbatched, queries) = build_engine(1, "live-ref");
    for (i, q) in queries.iter().take(8).enumerate() {
        let a = unbatched.handle(q).unwrap();
        let b = sched.handle(q).unwrap();
        assert_eq!(a.hits, b.hits, "query {i} (live vs prebuilt batched)");
    }
}

#[test]
fn backpressure_rejects_beyond_max_inflight() {
    let (_b, engine, queries) = build_engine(1, "backpressure");
    let sched = BatchScheduler::new(
        engine,
        SchedConfig {
            batch_window_us: 100,
            max_inflight: 1,
            deadline_us: test_deadline_us(),
            bypass: true,
        },
    );
    // Hold the only admission slot, then submit: must reject, not queue.
    let permit = sched.try_admit().unwrap();
    let err = sched.handle(&queries[0]).unwrap_err();
    assert!(
        format!("{err:#}").contains("overloaded"),
        "unexpected error: {err:#}"
    );
    assert_eq!(sched.stats().rejected, 1);
    drop(permit);
    // Slot released: the same query now serves fine.
    assert!(!sched.handle(&queries[0]).unwrap().hits.is_empty());
}

#[test]
fn shutdown_flushes_queued_work_and_serves_inline_after() {
    let (_b, engine, queries) = build_engine(1, "shutdown");
    // A huge window would hold partial batches for 10s; shutdown must
    // flush them promptly and later queries must fall back inline.
    let sched = BatchScheduler::new(
        engine,
        SchedConfig {
            batch_window_us: 10_000_000,
            max_inflight: 0,
            deadline_us: test_deadline_us(),
            bypass: false,
        },
    );
    let started = Instant::now();
    std::thread::scope(|scope| {
        let sched = &sched;
        let q = &queries[0];
        let h = scope.spawn(move || sched.handle(q).unwrap());
        // Let the query enqueue into the embed stage, then shut down.
        std::thread::sleep(Duration::from_millis(150));
        sched.shutdown();
        let out = h.join().unwrap();
        assert!(!out.hits.is_empty());
    });
    assert!(
        started.elapsed() < Duration::from_secs(9),
        "shutdown must flush the queued query, not wait out the window"
    );
    // Post-shutdown queries run inline (unbatched), still correct.
    let out = sched.handle(&queries[1]).unwrap();
    assert!(!out.hits.is_empty());
}

#[test]
fn deadline_closes_partial_batches_under_thin_load() {
    let (_b, engine, queries) = build_engine(1, "deadline");
    let sched = BatchScheduler::new(engine, sched_cfg(false));
    // 3 concurrent queries against width-32 stages: only the deadline
    // (or queue-drain) can close these batches, and everyone completes.
    std::thread::scope(|scope| {
        for t in 0..3usize {
            let sched = &sched;
            let q = &queries[t];
            scope.spawn(move || {
                let out = sched.handle(q).unwrap();
                assert!(!out.hits.is_empty(), "thread {t}");
            });
        }
    });
    let s = sched.stats();
    assert_eq!(s.embed.batched_items, 3);
    assert!(
        s.embed.full_width == 0,
        "3 items cannot fill a 32-wide batch: {s:?}"
    );
}
