//! Integration over the full coordinator stack: builder → index →
//! pipeline → metrics, for every Table-4 configuration on the tiny
//! dataset, plus the cross-config invariants the paper relies on.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::{BuiltDataset, SystemBuilder};
use edgerag::eval::harness::{run_workload, RunOptions};
use edgerag::eval::recall::recall_at_k;
use edgerag::testutil::shared_compute;

fn builder() -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None; // always fresh for tests
    b.retrieval.nprobe = 4;
    // Scale the cache to the tiny dataset (the default 4 MiB is ~8% of the
    // full device budget; tiny's whole index is only 512 KiB). Large
    // enough for a few of tiny's ~64-chunk clusters.
    b.retrieval.cache_capacity_bytes = 192 << 10;
    b
}

fn built(b: &SystemBuilder) -> BuiltDataset {
    b.build_dataset(&DatasetProfile::tiny()).unwrap()
}

#[test]
fn every_config_serves_the_tiny_workload() {
    let b = builder();
    let d = built(&b);
    let opts = RunOptions {
        query_limit: Some(30),
        ..Default::default()
    };
    for kind in IndexKind::ALL {
        let r = run_workload(&b, &d, kind, &opts).unwrap();
        assert_eq!(r.queries, 30, "{kind:?}");
        assert!(r.retrieval_mean.as_nanos() > 0, "{kind:?}");
        assert!(r.ttft_mean > r.retrieval_mean, "{kind:?} ttft > retrieval");
        assert!(r.quality.recall > 0.3, "{kind:?} recall {}", r.quality.recall);
        assert!(r.gen_score > 30.0, "{kind:?} gen score {}", r.gen_score);
    }
}

#[test]
fn ivf_and_edgerag_retrieval_identical() {
    // Paper §6.3.1: EdgeRAG produces identical retrieval results to the
    // two-level IVF index — so quality metrics must match exactly.
    let b = builder();
    let d = built(&b);
    let opts = RunOptions {
        query_limit: Some(40),
        ..Default::default()
    };
    let ivf = run_workload(&b, &d, IndexKind::Ivf, &opts).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts).unwrap();
    assert!((ivf.quality.recall - edge.quality.recall).abs() < 1e-9);
    assert!((ivf.quality.precision - edge.quality.precision).abs() < 1e-9);
    assert!((ivf.gen_score - edge.gen_score).abs() < 1e-9);
}

#[test]
fn flat_and_edge_recall_comparable() {
    // IVF-family recall tracks the flat baseline closely. (It is NOT a
    // strict lower bound: pruning unprobed clusters can *exclude*
    // high-scoring irrelevant competitors, so IVF recall occasionally
    // exceeds flat — observed on this fixture.)
    let b = builder();
    let d = built(&b);
    let opts = RunOptions {
        query_limit: Some(40),
        ..Default::default()
    };
    let flat = run_workload(&b, &d, IndexKind::Flat, &opts).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts).unwrap();
    assert!(
        (flat.quality.recall - edge.quality.recall).abs() < 0.1,
        "flat {} vs edge {}",
        flat.quality.recall,
        edge.quality.recall
    );
}

#[test]
fn nprobe_increases_recall_monotonically() {
    let b = builder();
    let d = built(&b);
    let mut last = 0.0;
    for nprobe in [1usize, 2, 4, 8] {
        let r = run_workload(
            &b,
            &d,
            IndexKind::IvfGen,
            &RunOptions {
                query_limit: Some(40),
                nprobe: Some(nprobe),
                ..Default::default()
            },
        )
        .unwrap();
        // Near-monotone: probing more clusters may admit higher-scoring
        // irrelevant competitors, so tiny dips are legitimate.
        assert!(
            r.quality.recall >= last - 0.03,
            "recall dropped at nprobe={nprobe}: {} < {last}",
            r.quality.recall
        );
        last = last.max(r.quality.recall);
    }
}

#[test]
fn edgerag_resident_memory_far_below_ivf() {
    let b = builder();
    let d = built(&b);
    let opts = RunOptions {
        query_limit: Some(10),
        ..Default::default()
    };
    let ivf = run_workload(&b, &d, IndexKind::Ivf, &opts).unwrap();
    let edge = run_workload(&b, &d, IndexKind::EdgeRag, &opts).unwrap();
    assert!(
        edge.resident_bytes * 2 < ivf.resident_bytes,
        "edge {} vs ivf {}",
        edge.resident_bytes,
        ivf.resident_bytes
    );
}

#[test]
fn repeat_queries_hit_cache_and_get_faster() {
    let b = builder();
    let d = built(&b);
    let pipeline = b.pipeline(&d, IndexKind::EdgeRag).unwrap();
    let q = &d.workload.queries[0].text;
    let cold = pipeline.handle(q).unwrap();
    let warm = pipeline.handle(q).unwrap();
    assert!(warm.events.cache_hits > 0);
    assert!(warm.retrieval < cold.retrieval);
}

#[test]
fn direct_query_of_chunk_text_retrieves_chunk() {
    let b = builder();
    let d = built(&b);
    let pipeline = b.pipeline(&d, IndexKind::EdgeRag).unwrap();
    let mut hits = 0;
    for id in [3u32, 99, 200, 400] {
        let out = pipeline.handle(&d.corpus.chunks[id as usize].text).unwrap();
        let retrieved: Vec<u32> = out.hits.iter().map(|h| h.0).collect();
        if recall_at_k(&retrieved, &[id]) > 0.0 {
            hits += 1;
        }
    }
    assert!(hits >= 3, "only {hits}/4 self-queries retrieved their chunk");
}

#[test]
fn tune_nprobe_converges() {
    let b = builder();
    let d = built(&b);
    let np = edgerag::eval::harness::tune_nprobe(&b, &d, 0.05, 20).unwrap();
    assert!(np >= 1 && np <= d.centroids.len());
}

#[test]
fn workload_runs_are_deterministic() {
    let b = builder();
    let d = built(&b);
    let opts = RunOptions {
        query_limit: Some(20),
        ..Default::default()
    };
    let a = run_workload(&b, &d, IndexKind::EdgeRag, &opts).unwrap();
    let c = run_workload(&b, &d, IndexKind::EdgeRag, &opts).unwrap();
    assert_eq!(a.retrieval_mean.as_nanos(), c.retrieval_mean.as_nanos());
    assert_eq!(a.quality.recall, c.quality.recall);
    assert_eq!(
        a.cache.map(|s| (s.hits, s.misses)),
        c.cache.map(|s| (s.hits, s.misses))
    );
}
