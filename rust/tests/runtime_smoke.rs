//! Integration: the AOT bridge end-to-end — rust loads the HLO artifacts
//! python lowered, compiles them through PJRT, executes, and the numerics
//! match CPU reference computations (which themselves match the pure-jnp
//! oracles validated by `python/tests/`).

use edgerag::embedding::{tokenizer, Embedder, EmbedderBackend};
use edgerag::index::Scorer;
use edgerag::runtime::Tensor;
use edgerag::testutil::shared_compute;
use edgerag::vecmath::{self, EmbeddingMatrix};

fn deterministic_rows(dim: usize, n: usize, seed: u64) -> EmbeddingMatrix {
    let mut rng = edgerag::data::Rng::new(seed);
    let mut m = EmbeddingMatrix::new(dim);
    for _ in 0..n {
        let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        m.push(&row);
    }
    m
}

#[test]
fn sim_artifact_matches_cpu_dot() {
    let compute = shared_compute();
    let dim = compute.dim();
    let rows = deterministic_rows(dim, 100, 1);
    let q = deterministic_rows(dim, 1, 2);

    let mut padded = rows.data.clone();
    padded.resize(128 * dim, 0.0);
    let out = compute
        .run(
            "sim_1x128",
            vec![
                Tensor::F32(q.data.clone(), vec![1, dim]),
                Tensor::F32(padded, vec![128, dim]),
            ],
        )
        .unwrap();
    assert_eq!(out[0].len(), 128);
    for i in 0..100 {
        let want = vecmath::dot(q.row(0), rows.row(i));
        assert!(
            (out[0][i] - want).abs() < 1e-3 * want.abs().max(1.0),
            "row {i}: {} vs {}",
            out[0][i],
            want
        );
    }
}

#[test]
fn scorer_chunks_large_inputs_correctly() {
    let compute = shared_compute();
    let scorer = Scorer::new(compute);
    let dim = scorer.dim();
    // 5000 rows > the largest (4096) bucket forces multi-call chunking.
    let rows = deterministic_rows(dim, 5000, 3);
    let q = deterministic_rows(dim, 1, 4);
    let scores = scorer.scores(q.row(0), &rows).unwrap();
    assert_eq!(scores.len(), 5000);
    for &i in &[0usize, 127, 128, 4095, 4096, 4999] {
        let want = vecmath::dot(q.row(0), rows.row(i));
        assert!(
            (scores[i] - want).abs() < 1e-3 * want.abs().max(1.0),
            "row {i}"
        );
    }
}

#[test]
fn scorer_top_k_finds_planted_neighbor() {
    let compute = shared_compute();
    let scorer = Scorer::new(compute);
    let dim = scorer.dim();
    let mut rows = deterministic_rows(dim, 300, 5);
    let q = deterministic_rows(dim, 1, 6);
    // Plant an exact copy of the query at row 123: must rank first.
    let target: Vec<f32> = q.row(0).to_vec();
    rows.data[123 * dim..124 * dim].copy_from_slice(&target);
    let top = scorer.top_k(q.row(0), &rows, 5).unwrap();
    assert_eq!(top[0].0, 123);
    assert_eq!(top.len(), 5);
}

#[test]
fn projection_embedder_unit_norm_and_deterministic() {
    let compute = shared_compute();
    let emb = Embedder::new(compute, EmbedderBackend::Projection);
    let texts = vec![
        "the quick brown fox",
        "retrieval augmented generation on edge devices",
        "a completely different sentence about storage",
    ];
    let a = emb.embed_texts(&texts).unwrap();
    let b = emb.embed_texts(&texts).unwrap();
    assert_eq!(a.len(), 3);
    for i in 0..3 {
        let norm = vecmath::l2_norm(a.row(i));
        assert!((norm - 1.0).abs() < 1e-3, "row {i} norm {norm}");
        assert_eq!(a.row(i), b.row(i), "must be deterministic");
    }
}

#[test]
fn projection_batching_invariant() {
    // Embedding 40 texts (32-bucket + padded 1-buckets) must equal
    // embedding them one at a time.
    let compute = shared_compute();
    let emb = Embedder::new(compute, EmbedderBackend::Projection);
    let texts: Vec<String> = (0..40)
        .map(|i| format!("text number {i} with words w{} w{}", i * 7 % 13, i % 5))
        .collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let batched = emb.embed_texts(&refs).unwrap();
    for i in [0usize, 15, 31, 32, 39] {
        let single = emb.embed_one(&texts[i]).unwrap();
        for (a, b) in batched.row(i).iter().zip(&single) {
            assert!((a - b).abs() < 1e-4, "text {i} differs");
        }
    }
}

#[test]
fn similar_texts_embed_closer_than_dissimilar() {
    let compute = shared_compute();
    let emb = Embedder::new(compute, EmbedderBackend::Projection);
    let base = "cluster embeddings are generated online during retrieval";
    let near = "cluster embeddings generated online during the retrieval";
    let far = "bananas oranges apples pears grapes melons";
    let m = emb.embed_texts(&[base, near, far]).unwrap();
    let sim_near = vecmath::dot(m.row(0), m.row(1));
    let sim_far = vecmath::dot(m.row(0), m.row(2));
    assert!(
        sim_near > sim_far + 0.2,
        "near {sim_near} vs far {sim_far}"
    );
}

#[test]
fn transformer_embedder_works_and_differs_from_projection() {
    let compute = shared_compute();
    let enc = Embedder::new(compute.clone(), EmbedderBackend::Transformer);
    let texts = vec!["edge devices run small language models", "hello world"];
    let m = enc.embed_texts(&texts).unwrap();
    assert_eq!(m.len(), 2);
    for i in 0..2 {
        assert!((vecmath::l2_norm(m.row(i)) - 1.0).abs() < 1e-3);
    }
    // semantic structure: a text is closer to itself re-embedded than to
    // the other text
    let again = enc.embed_texts(&[texts[0]]).unwrap();
    let self_sim = vecmath::dot(m.row(0), again.row(0));
    let cross = vecmath::dot(m.row(0), m.row(1));
    assert!(self_sim > 0.999 && cross < self_sim);
}

#[test]
fn prefill_artifact_runs() {
    let compute = shared_compute();
    let m = compute.manifest();
    let seq = m.prefill_seq;
    let mut ids = vec![0i32; seq];
    for (i, tid) in tokenizer::token_ids("what is the capital of france")
        .into_iter()
        .enumerate()
    {
        ids[i + 1] = tid;
    }
    ids[0] = tokenizer::CLS_ID;
    let out = compute
        .run("prefill_1", vec![Tensor::I32(ids, vec![1, seq])])
        .unwrap();
    assert_eq!(out[0].len(), m.vocab);
    assert!(out[0].iter().all(|x| x.is_finite()));
}

#[test]
fn batch_scores_matches_single_scores() {
    let compute = shared_compute();
    let scorer = Scorer::new(compute);
    let dim = scorer.dim();
    let points = deterministic_rows(dim, 40, 7);
    let cents = deterministic_rows(dim, 50, 8);
    let batch = scorer.batch_scores(&points, &cents).unwrap();
    assert_eq!(batch.len(), 40);
    assert_eq!(batch[0].len(), 50);
    for i in [0usize, 31, 39] {
        let single = scorer.scores(points.row(i), &cents).unwrap();
        for j in 0..50 {
            assert!(
                (batch[i][j] - single[j]).abs() < 1e-3,
                "point {i} cent {j}: {} vs {}",
                batch[i][j],
                single[j]
            );
        }
    }
}
