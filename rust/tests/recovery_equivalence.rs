//! Crash-recovery equivalence for the structural write-ahead log
//! (`rust/src/storage/wal.rs` + the recovery path in
//! `SystemBuilder::index`).
//!
//! The recovery invariant under test: **fresh build + replay of the
//! surviving log ≡ fresh build + the same external op sequence.** A
//! seeded churn (the `rebalance_churn` op mix) runs against a WAL'd
//! index and is killed at a seeded random op with no checkpoint; the
//! builder then recovers from the on-disk log alone, and every
//! observable — search hits, probed sets, cache events, modeled
//! latency, cluster-id allocation, cluster membership, the cross-shard
//! invariant suite — must be bit-identical to a fresh single-shard
//! oracle replaying the recorded op prefix. Cache and adaptive state
//! are defined **cold** after recovery on both sides (searches during
//! churn are uncommitted, so neither replica accumulates cache state).
//!
//! Three layers:
//!
//! 1. **Kill-at-random-op equivalence** at shards ∈ {1, 2, 4, 8}, with
//!    a snapshot interval small enough that rotation fires repeatedly
//!    mid-churn — recovery reads snapshot *and* tail.
//! 2. **Replay determinism** — recovering the same log twice yields
//!    bit-identical indexes, and a post-recovery insert allocates the
//!    same cluster id as the oracle (the allocator state recovered
//!    exactly).
//! 3. **Clean-shutdown checkpoint** — `wal_checkpoint` truncates the
//!    log into the snapshot; snapshot-only recovery is equivalent too.
//!
//! Plus shard-count portability: a log written at 4 shards recovers at
//! 2 and at 1 (out-of-range migrations are skipped; placement never
//! affects search results).

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::{BuiltDataset, SystemBuilder};
use edgerag::data::Rng;
use edgerag::embedding::Embedder;
use edgerag::index::{EdgeIndex, ShardedEdgeIndex, VectorIndex};
use edgerag::storage::WalOp;
use edgerag::testutil::{shared_compute, test_seed};

fn builder(shards: usize, tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    // Per-test state root: blob stores and WAL dirs must not collide
    // across parallel tests (and across the oracle/subject pair).
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-recov-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    b
}

/// Shard counts for the recovery sweep — the "oracle-exact at any N"
/// acceptance. `EDGERAG_TEST_SHARDS` pins one (the CI matrix).
fn shard_counts() -> Vec<usize> {
    match std::env::var("EDGERAG_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("EDGERAG_TEST_SHARDS must be an integer")],
        Err(_) => vec![1, 2, 4, 8],
    }
}

/// Pick a removal victim (same policy as `rebalance_churn`): half the
/// time a chunk from the smallest non-empty cluster of the lockstep
/// oracle (draining clusters through the merge threshold), otherwise a
/// uniformly random alive chunk.
fn removal_victim(rng: &mut Rng, oracle: &EdgeIndex, alive: &[u32]) -> u32 {
    if rng.below(2) == 0 {
        oracle
            .clusters()
            .clusters
            .iter()
            .filter(|m| !m.is_empty())
            .min_by_key(|m| (m.len(), m.id))
            .map(|m| m.chunk_ids[0])
            .expect("alive chunks imply a non-empty cluster")
    } else {
        alive[rng.below(alive.len())]
    }
}

/// One search's full observable surface.
type Observation = (Vec<(u32, f32)>, Vec<u32>, usize, usize, usize, edgerag::simtime::SimDuration);

/// Run a fixed query battery and capture every observable the oracle
/// comparison cares about: hits, probed set, cache events, modeled
/// latency. Searches are uncommitted — the battery itself is
/// side-effect-free and repeatable.
fn battery(idx: &dyn VectorIndex, qembs: &[Vec<f32>]) -> Vec<Observation> {
    qembs
        .iter()
        .map(|q| {
            let s = idx.search(q, 5).unwrap();
            (
                s.hits,
                s.probed,
                s.events.generated,
                s.events.loaded,
                s.events.cache_hits,
                s.ledger.total(),
            )
        })
        .collect()
}

/// Replay a recorded external-op trace into a fresh oracle through the
/// ordinary public update paths — the reference side of the recovery
/// invariant.
fn apply_trace(idx: &mut Box<dyn VectorIndex>, trace: &[WalOp]) {
    for op in trace {
        match op {
            WalOp::Insert { id, text, emb } => {
                idx.insert_chunk(*id, text, emb).unwrap();
            }
            WalOp::Remove { id } => {
                assert!(idx.remove_chunk(*id).unwrap(), "traced removal of {id}");
            }
            WalOp::PinThreshold { ms } => idx.pin_threshold(*ms),
            op => unreachable!("trace holds external replayable ops only, got {op:?}"),
        }
    }
}

fn active_clusters(idx: &dyn VectorIndex) -> usize {
    match idx.as_any().downcast_ref::<ShardedEdgeIndex>() {
        Some(s) => s.active_clusters(),
        None => idx.as_any().downcast_ref::<EdgeIndex>().unwrap().active_clusters(),
    }
}

fn cluster_of(idx: &dyn VectorIndex, id: u32) -> Option<u32> {
    match idx.as_any().downcast_ref::<ShardedEdgeIndex>() {
        Some(s) => s.cluster_of(id),
        None => idx.as_any().downcast_ref::<EdgeIndex>().unwrap().cluster_of(id),
    }
}

fn verify_if_sharded(idx: &dyn VectorIndex) {
    if let Some(s) = idx.as_any().downcast_ref::<ShardedEdgeIndex>() {
        s.verify_integrity().unwrap();
    }
}

/// Assert full structural agreement between a recovered index and the
/// oracle: membership of every tracked chunk, the surviving cluster
/// count, the invariant suite, and the query battery.
fn assert_oracle_equal(
    recovered: &dyn VectorIndex,
    oracle: &dyn VectorIndex,
    ids: &[u32],
    qembs: &[Vec<f32>],
    what: &str,
) {
    verify_if_sharded(recovered);
    assert_eq!(
        active_clusters(recovered),
        active_clusters(oracle),
        "{what}: active-cluster sets diverged"
    );
    for &id in ids {
        assert_eq!(
            cluster_of(recovered, id),
            cluster_of(oracle, id),
            "{what}: chunk {id} routed differently after recovery"
        );
    }
    assert_eq!(
        battery(recovered, qembs),
        battery(oracle, qembs),
        "{what}: search battery diverged"
    );
}

/// The seeded churn driven before the crash. Applies ops to the WAL'd
/// subject and a lockstep single-shard oracle (victim selection +
/// pre-crash sanity), recording every external structural op into the
/// trace the post-crash oracle replays.
struct Churn<'a> {
    rng: Rng,
    alive: Vec<u32>,
    next_id: u32,
    trace: Vec<WalOp>,
    embedder: &'a Embedder,
    built: &'a BuiltDataset,
}

impl<'a> Churn<'a> {
    fn new(seed: u64, embedder: &'a Embedder, built: &'a BuiltDataset) -> Churn<'a> {
        Churn {
            rng: Rng::new(seed),
            alive: (0..built.corpus.len() as u32).collect(),
            next_id: built.corpus.len() as u32 + 1_000,
            trace: Vec::new(),
            embedder,
            built,
        }
    }

    /// One churn step (the `rebalance_churn` op mix): search 35%,
    /// insert 20%, remove 30%, rebalance 15%.
    fn step(
        &mut self,
        subject: &mut Box<dyn VectorIndex>,
        oracle: &mut Box<dyn VectorIndex>,
        step: usize,
    ) {
        match self.rng.below(100) {
            0..=34 => {
                let queries = &self.built.workload.queries;
                let q = &queries[self.rng.below(queries.len())];
                let emb = self.embedder.embed_one(&q.text).unwrap();
                let sa = oracle.search(&emb, 5).unwrap();
                let sb = subject.search(&emb, 5).unwrap();
                assert_eq!(sa.hits, sb.hits, "pre-crash step {step} hits");
                assert_eq!(sa.probed, sb.probed, "pre-crash step {step} probes");
            }
            35..=54 => {
                let id = self.next_id;
                let text = format!("churn document {id} marker zzchurn{id}");
                let emb = self.embedder.embed_one(&text).unwrap();
                let ca = oracle.insert_chunk(id, &text, &emb).unwrap();
                let cb = if subject.supports_concurrent_updates() {
                    subject.insert_chunk_concurrent(id, &text, &emb).unwrap()
                } else {
                    subject.insert_chunk(id, &text, &emb).unwrap()
                };
                assert_eq!(ca, cb, "pre-crash step {step}: cluster-id allocation diverged");
                self.trace.push(WalOp::Insert { id, text, emb });
                self.alive.push(id);
                self.next_id += 1;
            }
            55..=84 => {
                if self.alive.is_empty() {
                    return;
                }
                let id = removal_victim(
                    &mut self.rng,
                    oracle.as_any().downcast_ref::<EdgeIndex>().unwrap(),
                    &self.alive,
                );
                let ra = oracle.remove_chunk(id).unwrap();
                let rb = if subject.supports_concurrent_updates() {
                    subject.remove_chunk_concurrent(id).unwrap()
                } else {
                    subject.remove_chunk(id).unwrap()
                };
                assert_eq!(ra, rb, "pre-crash step {step} removed flags");
                assert!(ra, "pre-crash step {step}: alive chunk not removed");
                self.trace.push(WalOp::Remove { id });
                let i = self.alive.iter().position(|&a| a == id).unwrap();
                self.alive.swap_remove(i);
            }
            _ => {
                // Rebalance: migrations are logged as Migrate records
                // and replayed positionally; the single-shard oracle has
                // nothing to move, so the trace records nothing.
                if let Some(sharded) = subject.as_any().downcast_ref::<ShardedEdgeIndex>() {
                    sharded.rebalance().unwrap();
                    sharded.verify_integrity().unwrap();
                }
            }
        }
    }
}

#[test]
fn kill_at_random_op_recovers_to_oracle_exact_index() {
    let seed = test_seed(0x4EC0);
    for shards in shard_counts() {
        let tag = format!("kill-{shards}");

        // Lockstep oracle (no WAL): removal-victim selection and
        // pre-crash sanity checks.
        let b_live = builder(1, &format!("{tag}-live"));
        let built_live = b_live.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut live_oracle, _ml) = b_live.index(&built_live, IndexKind::EdgeRag).unwrap();

        // Subject: WAL on, snapshot interval small enough that churn
        // rotates the log several times — recovery must merge snapshot
        // and tail, not just read a flat log.
        let mut b = builder(shards, &tag);
        b.retrieval.wal = true;
        b.retrieval.snapshot_interval_ops = 16;
        let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
        let wal_dir = b
            .options
            .state_dir
            .join(&built.profile.name)
            .join(format!("{}-wal", IndexKind::EdgeRag.name()));
        let _ = std::fs::remove_dir_all(&wal_dir);
        let (mut subject, _ms) = b.index(&built, IndexKind::EdgeRag).unwrap();

        let embedder = b.embedder();
        let mut churn = Churn::new(seed ^ shards as u64, &embedder, &built);

        // Pin the threshold through the WAL'd path: the pin must itself
        // be recovered (a lost pin would re-enable adaptation and
        // diverge modeled latency).
        subject.pin_threshold(0.0);
        live_oracle.pin_threshold(0.0);
        churn.trace.push(WalOp::PinThreshold { ms: 0.0 });

        // Churn, then crash at a seeded random op: drop the index with
        // no checkpoint. The on-disk snapshot + log is all that survives.
        let kill_at = 120 + churn.rng.below(120);
        for step in 0..kill_at {
            churn.step(&mut subject, &mut live_oracle, step);
        }
        drop(subject);
        drop(live_oracle);

        // The post-crash reference: a fresh single-shard build replaying
        // the recorded external-op prefix.
        let b_fresh = builder(1, &format!("{tag}-fresh"));
        let built_fresh = b_fresh.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut oracle, _mf) = b_fresh.index(&built_fresh, IndexKind::EdgeRag).unwrap();
        apply_trace(&mut oracle, &churn.trace);

        let qembs: Vec<Vec<f32>> = built
            .workload
            .queries
            .iter()
            .take(24)
            .map(|q| embedder.embed_one(&q.text).unwrap())
            .collect();

        // Recover through the builder path (fresh build + replay of the
        // surviving log + attach) and demand full structural agreement.
        let (recovered, _mr) = b.index(&built, IndexKind::EdgeRag).unwrap();
        assert_oracle_equal(
            recovered.as_ref(),
            oracle.as_ref(),
            &churn.alive,
            &qembs,
            &format!("shards={shards} first recovery"),
        );
        let first_battery = battery(recovered.as_ref(), &qembs);
        drop(recovered);

        // Replay determinism: the same log recovers to a bit-identical
        // index every time.
        let (recovered, _mr) = b.index(&built, IndexKind::EdgeRag).unwrap();
        assert_eq!(
            battery(recovered.as_ref(), &qembs),
            first_battery,
            "shards={shards}: two recoveries of one log diverged"
        );

        // The allocator state recovered exactly: the next insert lands
        // in the same (globally numbered) cluster on both sides — and is
        // itself logged, so the next recovery must carry it too.
        let mut recovered = recovered;
        let id = churn.next_id;
        let text = format!("churn document {id} marker zzchurn{id}");
        let emb = embedder.embed_one(&text).unwrap();
        let ca = oracle.insert_chunk(id, &text, &emb).unwrap();
        let cb = if recovered.supports_concurrent_updates() {
            recovered.insert_chunk_concurrent(id, &text, &emb).unwrap()
        } else {
            recovered.insert_chunk(id, &text, &emb).unwrap()
        };
        assert_eq!(
            ca, cb,
            "shards={shards}: post-recovery insert allocated a different cluster id"
        );
        let mut ids = churn.alive.clone();
        ids.push(id);

        // Clean shutdown: checkpoint consolidates the log into the
        // snapshot; recovery must then reconstruct from the snapshot
        // alone — including the post-recovery insert.
        recovered.wal_checkpoint().unwrap();
        assert_eq!(
            std::fs::metadata(wal_dir.join("wal.log")).unwrap().len(),
            0,
            "checkpoint must truncate the log"
        );
        assert!(
            wal_dir.join("wal.snapshot").exists(),
            "checkpoint must publish a snapshot"
        );
        drop(recovered);

        let (recovered, _mr) = b.index(&built, IndexKind::EdgeRag).unwrap();
        assert!(
            cluster_of(recovered.as_ref(), id).is_some(),
            "shards={shards}: snapshot-only recovery lost the post-recovery insert"
        );
        assert_oracle_equal(
            recovered.as_ref(),
            oracle.as_ref(),
            &ids,
            &qembs,
            &format!("shards={shards} snapshot-only recovery"),
        );
    }
}

#[test]
fn log_written_during_resharding_recovers_at_a_different_shard_count() {
    // Reshard-era logs: ops recorded while the live shard count grew and
    // shrank (2 → 4 → 1 → 6 → 3 → 2) must recover on builds with a
    // *fixed* — and different — shard count. Shrink-driven migrations
    // land in the log like any others; replay skips destinations that
    // don't exist at the recovery count, tombstone evacuations are not
    // logged at all (replay re-derives merges from the removes), and
    // placement never affects search results — so every recovery must be
    // oracle-exact.
    let seed = test_seed(0x4E5D);
    let tag = "rs-portable";

    let b_live = builder(1, &format!("{tag}-live"));
    let built_live = b_live.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (mut live_oracle, _ml) = b_live.index(&built_live, IndexKind::EdgeRag).unwrap();

    let mut b2 = builder(2, tag);
    b2.retrieval.wal = true;
    b2.retrieval.snapshot_interval_ops = 16;
    let built = b2.build_dataset(&DatasetProfile::tiny()).unwrap();
    let wal_dir = b2
        .options
        .state_dir
        .join(&built.profile.name)
        .join(format!("{}-wal", IndexKind::EdgeRag.name()));
    let _ = std::fs::remove_dir_all(&wal_dir);
    let (mut subject, _ms) = b2.index(&built, IndexKind::EdgeRag).unwrap();

    let embedder = b2.embedder();
    let mut churn = Churn::new(seed, &embedder, &built);
    let targets = [4usize, 1, 6, 3, 2];
    for (round, &target) in targets.iter().enumerate() {
        for step in 0..24 {
            churn.step(&mut subject, &mut live_oracle, round * 24 + step);
        }
        let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
        let r = sharded.reshard(target).unwrap();
        assert_eq!(sharded.shards(), target, "round {round}: {r:?}");
        // Fill freshly grown shards so later shrink rounds log real
        // drain migrations.
        sharded.rebalance().unwrap();
        sharded.verify_integrity().unwrap();
    }
    drop(subject);
    drop(live_oracle);

    let b_fresh = builder(1, &format!("{tag}-fresh"));
    let built_fresh = b_fresh.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (mut oracle, _mf) = b_fresh.index(&built_fresh, IndexKind::EdgeRag).unwrap();
    apply_trace(&mut oracle, &churn.trace);

    let qembs: Vec<Vec<f32>> = built
        .workload
        .queries
        .iter()
        .take(16)
        .map(|q| embedder.embed_one(&q.text).unwrap())
        .collect();

    for shards in [8usize, 4, 2, 1] {
        let mut bn = b2.clone();
        bn.retrieval.shards = shards;
        let (recovered, _mr) = bn.index(&built, IndexKind::EdgeRag).unwrap();
        assert_oracle_equal(
            recovered.as_ref(),
            oracle.as_ref(),
            &churn.alive,
            &qembs,
            &format!("reshard-era recovery at shards={shards}"),
        );
    }
}

#[test]
fn log_written_at_four_shards_recovers_at_two_and_one() {
    // Shard-count portability: placement is the only thing Migrate
    // records carry, and placement never affects results — so a log
    // taken at 4 shards must recover on a 2-shard (migrations to shards
    // ≥ 2 skipped) and a single-shard (all migrations skipped) build,
    // oracle-exactly.
    let seed = test_seed(0xD05D);
    let tag = "portable";

    let b_live = builder(1, &format!("{tag}-live"));
    let built_live = b_live.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (mut live_oracle, _ml) = b_live.index(&built_live, IndexKind::EdgeRag).unwrap();

    let mut b4 = builder(4, tag);
    b4.retrieval.wal = true;
    b4.retrieval.snapshot_interval_ops = 16;
    let built = b4.build_dataset(&DatasetProfile::tiny()).unwrap();
    let wal_dir = b4
        .options
        .state_dir
        .join(&built.profile.name)
        .join(format!("{}-wal", IndexKind::EdgeRag.name()));
    let _ = std::fs::remove_dir_all(&wal_dir);
    let (mut subject, _ms) = b4.index(&built, IndexKind::EdgeRag).unwrap();

    let embedder = b4.embedder();
    let mut churn = Churn::new(seed, &embedder, &built);
    for step in 0..80 {
        churn.step(&mut subject, &mut live_oracle, step);
    }

    // Guarantee Migrate records whose destination does not exist on the
    // down-shard recoveries: push four clusters explicitly to the two
    // highest shards, then crash.
    {
        let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
        let globals: Vec<u32> = sharded
            .cluster_loads()
            .iter()
            .flatten()
            .map(|c| c.global)
            .take(4)
            .collect();
        for (i, &g) in globals.iter().enumerate() {
            sharded.migrate_cluster(g, 2 + i % 2).unwrap();
        }
        sharded.verify_integrity().unwrap();
    }
    drop(subject);
    drop(live_oracle);

    let b_fresh = builder(1, &format!("{tag}-fresh"));
    let built_fresh = b_fresh.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (mut oracle, _mf) = b_fresh.index(&built_fresh, IndexKind::EdgeRag).unwrap();
    apply_trace(&mut oracle, &churn.trace);

    let qembs: Vec<Vec<f32>> = built
        .workload
        .queries
        .iter()
        .take(16)
        .map(|q| embedder.embed_one(&q.text).unwrap())
        .collect();

    for shards in [4usize, 2, 1] {
        let mut bn = b4.clone();
        bn.retrieval.shards = shards;
        let (recovered, _mr) = bn.index(&built, IndexKind::EdgeRag).unwrap();
        assert_oracle_equal(
            recovered.as_ref(),
            oracle.as_ref(),
            &churn.alive,
            &qembs,
            &format!("portable recovery at shards={shards}"),
        );
    }
}
