//! Integration over the TCP serving layer: real sockets, the line-JSON
//! protocol, concurrent clients, online updates through the wire.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::json::Value;
use edgerag::server::{Client, Server};
use edgerag::testutil::shared_compute;

fn spawn_server() -> (std::net::SocketAddr, usize) {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let n = built.corpus.len();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server = Server::bind("127.0.0.1:0", pipeline, b.embedder()).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    (addr, n)
}

#[test]
fn full_protocol_roundtrip() {
    let (addr, corpus_len) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();

    // ping
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));

    // query
    let resp = c.query("c1 c2 some words t0w1 t0w2").unwrap();
    let hits = resp.get("hits").unwrap().as_array().unwrap();
    assert!(!hits.is_empty());
    assert!(resp.get("retrieval_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    // insert + retrieve it
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("completely unique marker xqzzy document")),
        ]))
        .unwrap();
    let id = ins.get("id").unwrap().as_u64().unwrap();
    assert!(id >= corpus_len as u64);
    let found = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = found
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(ids.contains(&id), "{ids:?} missing {id}");

    // remove + verify gone
    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(id as f64)),
        ]))
        .unwrap();
    assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true));
    let after = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = after
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(!ids.contains(&id));

    // stats
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    assert!(stats.get("queries").unwrap().as_u64().unwrap() >= 3);

    // bad request surfaces an error, not a disconnect
    let err = c.call(&Value::object(vec![("op", Value::str("nope"))])).unwrap();
    assert!(err.get("error").is_some());
    // connection still usable
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn concurrent_clients_are_serialized_safely() {
    let (addr, _) = spawn_server();
    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..5 {
                let resp = c.query(&format!("thread {t} query {i} c3 c4")).unwrap();
                assert!(resp.get("hits").is_some(), "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
