//! Integration over the TCP serving layer: real sockets, the line-JSON
//! protocol, concurrent clients, online updates through the wire. The
//! stress test drives N parallel clients through interleaved
//! query/insert/stats/remove ops against the worker-pool server.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::json::Value;
use edgerag::server::{Client, Server};
use edgerag::testutil::shared_compute;

fn spawn_server_with_workers(workers: usize) -> (std::net::SocketAddr, usize) {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let n = built.corpus.len();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_workers("127.0.0.1:0", pipeline, b.embedder(), workers).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    (addr, n)
}

fn spawn_server() -> (std::net::SocketAddr, usize) {
    spawn_server_with_workers(4)
}

#[test]
fn batched_server_serves_and_reports_stage_stats() {
    // End-to-end over TCP with the cross-query batch scheduler enabled
    // (the `edgerag serve` default): concurrent clients get correct
    // results and the stats endpoint exposes per-stage scheduler rows.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.batch_window_us = 200;
    // Generous explicit deadline: the plumbing is armed (stamped at
    // admission, riders close batches) but can never fire — this test
    // asserts exact submitted counts.
    b.retrieval.deadline_us = 60_000_000;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 4, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..6 {
                let resp = c.query(&format!("batched thread {t} query {i} c1 t0w1")).unwrap();
                assert!(resp.get("hits").is_some(), "{resp}");
                assert!(resp.get("error").is_none(), "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let mut c = Client::connect(&addr.to_string()).unwrap();
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let sched = stats.get("sched").expect("batched server exposes sched stats");
    assert_eq!(
        sched.get("submitted").and_then(|v| v.as_u64()),
        Some(24),
        "{sched}"
    );
    for stage in ["embed", "probe"] {
        let s = sched.get(stage).unwrap_or_else(|| panic!("missing {stage}: {sched}"));
        // Bypassed queries skip the stages; batched ones must balance:
        // submitted items all came back through fused batches.
        let submitted = s.get("submitted").and_then(|v| v.as_u64()).unwrap();
        let batches = s.get("batches").and_then(|v| v.as_u64()).unwrap();
        assert!(batches <= submitted, "{stage}: {s}");
    }
}

/// Minimal Prometheus text-exposition parser: `(name, labels, value)`
/// triples, panicking on any malformed line (bad metric name, missing
/// value, unterminated label set, or a sample with no preceding
/// `# TYPE` for its family).
fn parse_prometheus(body: &str) -> Vec<(String, String, f64)> {
    let mut typed = HashSet::new();
    let mut samples = Vec::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let name = rest.split_whitespace().next().expect("bare # TYPE line");
            typed.insert(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (metric, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("no value on line: {line}"));
        let value: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("bad sample value: {line}"));
        let (name, labels) = match metric.split_once('{') {
            Some((n, l)) => {
                assert!(l.ends_with('}'), "unterminated label set: {line}");
                (n.to_string(), l[..l.len() - 1].to_string())
            }
            None => (metric.to_string(), String::new()),
        };
        assert!(
            !name.is_empty()
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name: {line}"
        );
        let family = name
            .trim_end_matches("_bucket")
            .trim_end_matches("_sum")
            .trim_end_matches("_count");
        assert!(
            typed.contains(&name) || typed.contains(family),
            "sample before its # TYPE line: {line}"
        );
        samples.push((name, labels, value));
    }
    samples
}

#[test]
fn traced_server_exposes_span_trees_and_prometheus_metrics() {
    // The tracing-plane acceptance test: a traced query's span tree
    // covers admission, embedding, the search (per-shard walks + cache
    // outcome) and prefill; a traced insert shows the WAL append; the
    // `metrics` op renders parseable Prometheus text.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-traceint-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&b.options.state_dir);
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.trace = true;
    b.retrieval.slow_query_us = 0; // every request crosses the slow threshold
    b.retrieval.wal = true;
    b.options.wal_dir = Some(b.options.state_dir.join("wal"));
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 4, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    let mut c = Client::connect(&addr.to_string()).unwrap();

    // A traced query stamps a resolvable trace id into its response…
    let resp = c.query("traced query c1 t0w1").unwrap();
    let qid = resp
        .get("trace_id")
        .and_then(|v| v.as_u64())
        .unwrap_or_else(|| panic!("query response missing trace_id: {resp}"));
    let qt = c
        .call(&Value::object(vec![
            ("op", Value::str("trace")),
            ("id", Value::num(qid as f64)),
        ]))
        .unwrap();
    assert_eq!(qt.get("id").and_then(|v| v.as_u64()), Some(qid), "{qt}");
    let span_names = |t: &Value| -> Vec<String> {
        t.get("spans")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap().to_string())
            .collect()
    };
    let names = span_names(&qt);
    // …whose span tree covers the whole pipeline. A lone query rides the
    // scheduler bypass (inline embedding); under load the same slots are
    // filled by `embed.wait`/`embed.exec` with batch-width attribution.
    for required in [
        "admission",
        "search",
        "shard.walk",
        "cache.outcome",
        "chunk_fetch",
        "prefill",
        "commit",
    ] {
        assert!(names.iter().any(|n| n == required), "span `{required}` missing: {names:?}");
    }
    assert!(
        names.iter().any(|n| n == "embed.exec" || n == "embed.inline"),
        "no embedding span: {names:?}"
    );

    // A traced insert shows the index mutation and the WAL append.
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("traced insert marker vwxyq")),
        ]))
        .unwrap();
    let iid = ins
        .get("trace_id")
        .and_then(|v| v.as_u64())
        .unwrap_or_else(|| panic!("insert response missing trace_id: {ins}"));
    let it = c
        .call(&Value::object(vec![
            ("op", Value::str("trace")),
            ("id", Value::num(iid as f64)),
        ]))
        .unwrap();
    let inames = span_names(&it);
    for required in ["admission", "insert.apply", "wal.append"] {
        assert!(
            inames.iter().any(|n| n == required),
            "insert span `{required}` missing: {inames:?}"
        );
    }

    // The ring listing sees both; threshold 0 fills the slow ring too.
    let listing = c.call(&Value::object(vec![("op", Value::str("trace"))])).unwrap();
    assert_eq!(listing.get("slow_threshold_us").and_then(|v| v.as_u64()), Some(0));
    assert!(!listing.get("recent").unwrap().as_array().unwrap().is_empty());
    assert!(!listing.get("slow").unwrap().as_array().unwrap().is_empty());

    // `stats` exposes the WAL activity block.
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let wal = stats
        .get("wal")
        .unwrap_or_else(|| panic!("stats missing wal block: {stats}"));
    assert!(
        wal.get("frames_appended").and_then(|v| v.as_u64()).unwrap() >= 1,
        "{wal}"
    );

    // `metrics` renders valid Prometheus text exposition.
    let met = c.call(&Value::object(vec![("op", Value::str("metrics"))])).unwrap();
    let body = met.get("body").unwrap().as_str().unwrap();
    let samples = parse_prometheus(body);
    let sample = |name: &str, label_frag: &str| -> f64 {
        samples
            .iter()
            .find(|(n, l, _)| n == name && (label_frag.is_empty() || l.contains(label_frag)))
            .map(|&(_, _, v)| v)
            .unwrap_or_else(|| panic!("metric `{name}` ({label_frag:?}) missing"))
    };
    assert!(sample("edgerag_queries_total", "") >= 1.0);
    assert!(sample("edgerag_wal_frames_appended_total", "") >= 1.0);
    assert!(sample("edgerag_sched_requests_total", "outcome=\"submitted\"") >= 1.0);
    assert!(sample("edgerag_traces_total", "state=\"finished\"") >= 2.0);
    // Histogram consistency: buckets cumulative, +Inf equals _count.
    for family in ["edgerag_retrieval_latency_seconds", "edgerag_ttft_latency_seconds"] {
        let buckets: Vec<f64> = samples
            .iter()
            .filter(|(n, _, _)| n == &format!("{family}_bucket"))
            .map(|&(_, _, v)| v)
            .collect();
        assert!(!buckets.is_empty(), "{family} has no buckets");
        assert!(
            buckets.windows(2).all(|w| w[0] <= w[1]),
            "{family} buckets not cumulative: {buckets:?}"
        );
        assert_eq!(*buckets.last().unwrap(), sample(&format!("{family}_count"), ""));
        assert!(sample(&format!("{family}_sum"), "") > 0.0);
    }
}

#[test]
fn full_protocol_roundtrip() {
    let (addr, corpus_len) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();

    // ping
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));

    // query
    let resp = c.query("c1 c2 some words t0w1 t0w2").unwrap();
    let hits = resp.get("hits").unwrap().as_array().unwrap();
    assert!(!hits.is_empty());
    assert!(resp.get("retrieval_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    // insert + retrieve it
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("completely unique marker xqzzy document")),
        ]))
        .unwrap();
    let id = ins.get("id").unwrap().as_u64().unwrap();
    assert!(id >= corpus_len as u64);
    let found = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = found
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(ids.contains(&id), "{ids:?} missing {id}");

    // remove + verify gone
    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(id as f64)),
        ]))
        .unwrap();
    assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true));
    let after = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = after
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(!ids.contains(&id));

    // stats
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    assert!(stats.get("queries").unwrap().as_u64().unwrap() >= 3);

    // bad request surfaces an error, not a disconnect
    let err = c.call(&Value::object(vec![("op", Value::str("nope"))])).unwrap();
    assert!(err.get("error").is_some());
    // connection still usable
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn query_containing_the_word_shutdown_does_not_kill_the_server() {
    // Regression: shutdown used to substring-match the raw request line.
    let (addr, _) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let resp = c.query("how do I shutdown my edge device safely \"shutdown\"").unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");
    // The server is still alive: a fresh connection works.
    let mut c2 = Client::connect(&addr.to_string()).unwrap();
    let pong = c2.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn concurrent_clients_run_in_parallel_safely() {
    let (addr, _) = spawn_server();
    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..5 {
                let resp = c.query(&format!("thread {t} query {i} c3 c4")).unwrap();
                assert!(resp.get("hits").is_some(), "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn stress_parallel_clients_interleave_query_insert_stats() {
    // The tentpole acceptance test: N parallel clients mixing reads
    // (query/stats) and writes (insert/remove) must finish without
    // deadlock, allocate globally unique ids, and observe monotone
    // metrics counters.
    let (addr, corpus_len) = spawn_server_with_workers(4);
    const THREADS: usize = 8;
    const OPS: usize = 16;

    let inserted: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let addr = addr.to_string();
        let inserted = inserted.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let mut my_ids = Vec::new();
            let mut last_queries = 0u64;
            for i in 0..OPS {
                match i % 4 {
                    // reads dominate, like real traffic
                    0 | 1 => {
                        let resp = c
                            .query(&format!("stress thread {t} op {i} c1 t0w1"))
                            .unwrap();
                        assert!(resp.get("hits").is_some(), "{resp}");
                        assert!(resp.get("error").is_none(), "{resp}");
                    }
                    2 => {
                        let text = format!("stress doc from thread {t} op {i} marker zq{t}x{i}");
                        let ins = c
                            .call(&Value::object(vec![
                                ("op", Value::str("insert")),
                                ("text", Value::str(text)),
                            ]))
                            .unwrap();
                        let id = ins.get("id").and_then(|v| v.as_u64()).unwrap_or_else(|| {
                            panic!("insert failed: {ins}")
                        });
                        my_ids.push(id);
                    }
                    _ => {
                        let stats = c
                            .call(&Value::object(vec![("op", Value::str("stats"))]))
                            .unwrap();
                        let q = stats.get("queries").and_then(|v| v.as_u64()).unwrap();
                        assert!(
                            q >= last_queries,
                            "queries counter went backwards: {q} < {last_queries}"
                        );
                        last_queries = q;
                    }
                }
            }
            // Remove one of our docs through the wire, too.
            if let Some(&id) = my_ids.first() {
                let rem = c
                    .call(&Value::object(vec![
                        ("op", Value::str("remove")),
                        ("id", Value::num(id as f64)),
                    ]))
                    .unwrap();
                assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true), "{rem}");
                my_ids.remove(0);
            }
            inserted.lock().unwrap().extend(my_ids);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Ids are globally unique and allocated past the corpus.
    let ids = inserted.lock().unwrap().clone();
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(unique.len(), ids.len(), "duplicate ids: {ids:?}");
    assert!(ids.iter().all(|&id| id >= corpus_len as u64));

    // Surviving inserts are retrievable; the query counter matches the
    // exact number of query ops served.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let t0_doc = c.query("stress doc thread 0 marker zq0x6").unwrap();
    assert!(t0_doc.get("hits").is_some());
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let total_queries = stats.get("queries").and_then(|v| v.as_u64()).unwrap();
    let expected = (THREADS * OPS / 2) as u64 + 1; // i%4 ∈ {0,1} per thread + this probe
    assert_eq!(total_queries, expected);
}

// ---------------------------------------------------------------------------
// The reactor-era adversarial-client suite: partial writers, pipelining,
// idle keep-alive fleets, overload visibility, deadline shedding, and
// shutdown-under-load — everything the thread-per-connection front end
// handled by accident or not at all.
// ---------------------------------------------------------------------------

/// Read one `\n`-terminated response line off a raw socket.
fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    let n = r.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection mid-conversation");
    line
}

#[test]
fn remove_rejects_out_of_range_ids() {
    // Regression: `as_u64()? as u32` silently truncated ids, so remove
    // with id 2^32+n deleted chunk n. Out-of-range ids must error, and
    // the aliased low id must be untouched.
    let (addr, _) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("truncation canary marker qwfpz")),
        ]))
        .unwrap();
    let id = ins.get("id").and_then(|v| v.as_u64()).unwrap();

    // The id that would alias onto `id` if the server truncated to u32.
    let aliased = id + (1u64 << 32);
    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(aliased as f64)),
        ]))
        .unwrap();
    let err = rem
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("out-of-range remove must error: {rem}"));
    assert!(err.contains("out of range"), "{err}");

    // The canary survived: no truncated-id deletion happened.
    let found = c.query("truncation canary qwfpz").unwrap();
    let ids: Vec<u64> = found
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(ids.contains(&id), "canary {id} was removed: {ids:?}");

    // The same id in range removes fine.
    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(id as f64)),
        ]))
        .unwrap();
    assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true), "{rem}");
}

#[test]
fn slow_and_partial_line_writers_are_served() {
    // A client that dribbles its request byte-group by byte-group (or
    // ships two requests in one segment) exercises the reactor's
    // buffered line reassembly; the blocking front end got this free
    // from `read_line`, the reactor must reproduce it.
    let (addr, _) = spawn_server();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // One ping, written in four fragments with pauses between them.
    let ping = b"{\"op\":\"ping\"}\n";
    for chunk in ping.chunks(4) {
        w.write_all(chunk).unwrap();
        w.flush().unwrap();
        std::thread::sleep(Duration::from_millis(20));
    }
    let resp = edgerag::json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp}");

    // A real query split mid-JSON across two writes.
    let q = b"{\"op\":\"query\",\"text\":\"partial writer query c1 t0w1\"}\n";
    let (head, tail) = q.split_at(17);
    w.write_all(head).unwrap();
    w.flush().unwrap();
    std::thread::sleep(Duration::from_millis(30));
    w.write_all(tail).unwrap();
    w.flush().unwrap();
    let resp = edgerag::json::parse(&read_line(&mut r)).unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");

    // Two pipelined requests in a single write: responses come back in
    // request order (ping's `ok` first, then the query's `hits`).
    let mut both = Vec::new();
    both.extend_from_slice(b"{\"op\":\"ping\"}\n");
    both.extend_from_slice(b"{\"op\":\"query\",\"text\":\"pipelined pair c2\"}\n");
    w.write_all(&both).unwrap();
    w.flush().unwrap();
    let first = edgerag::json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(first.get("ok").and_then(|v| v.as_bool()), Some(true), "{first}");
    let second = edgerag::json::parse(&read_line(&mut r)).unwrap();
    assert!(second.get("hits").is_some(), "{second}");
}

#[cfg(target_os = "linux")]
fn process_thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line in /proc/self/status")
        .trim()
        .parse()
        .unwrap()
}

#[cfg(target_os = "linux")]
#[test]
fn idle_keepalive_connections_spawn_no_threads() {
    // 200 live keep-alive connections against the reactor must not grow
    // the process by 200 handler threads (the thread-per-connection
    // front end did exactly that). Other tests run threads in this
    // process concurrently, so the bound is generous — the regression
    // signal is ~200, the noise is tens.
    let (addr, _) = spawn_server_with_workers(2);
    let before = process_thread_count();
    let mut conns = Vec::new();
    for i in 0..200 {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        // Each connection proves it is served, then stays open idle.
        w.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        let resp = edgerag::json::parse(&read_line(&mut r)).unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "conn {i}");
        conns.push((w, r));
    }
    let after = process_thread_count();
    let grown = after.saturating_sub(before);
    assert!(
        grown < 100,
        "200 idle connections grew the process by {grown} threads \
         (thread-per-connection regression)"
    );
    drop(conns);
}

#[test]
fn overload_rejections_are_visible_without_batching() {
    // Regression: the rejected counter lived on the batch scheduler, so
    // with batching off (`bind_with_workers`-style deployments) admission
    // rejections were invisible. It is a server-level stat now, on both
    // paths.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.batching = false; // the path that used to lose the count
    b.retrieval.max_inflight = 1; // 1 queued beyond the 1 executing
    b.retrieval.deadline_us = 60_000_000; // generous: sheds can't mask rejects
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 1, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    // Pipeline a burst far beyond worker + queue capacity in one write:
    // the reactor parses and submits them in one sweep, so most must be
    // turned away at admission.
    const BURST: usize = 16;
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut payload = Vec::new();
    for i in 0..BURST {
        payload
            .extend_from_slice(format!("{{\"op\":\"query\",\"text\":\"burst {i} c1\"}}\n").as_bytes());
    }
    w.write_all(&payload).unwrap();
    w.flush().unwrap();

    let mut served = 0u64;
    let mut rejected = 0u64;
    for _ in 0..BURST {
        let resp = edgerag::json::parse(&read_line(&mut r)).unwrap();
        match resp.get("error").and_then(|v| v.as_str()) {
            Some(err) => {
                assert!(err.contains("overloaded"), "unexpected error: {err}");
                rejected += 1;
            }
            None => {
                assert!(resp.get("hits").is_some(), "{resp}");
                served += 1;
            }
        }
    }
    assert!(served >= 1, "nothing served out of the burst");
    assert!(rejected >= 1, "nothing rejected: queue bound not enforced");

    // The exact count is on the server-level stats block…
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let srv = stats
        .get("server")
        .unwrap_or_else(|| panic!("stats missing server block: {stats}"));
    assert_eq!(srv.get("rejected").and_then(|v| v.as_u64()), Some(rejected), "{srv}");

    // …and on the Prometheus page, with batching off.
    let met = c.call(&Value::object(vec![("op", Value::str("metrics"))])).unwrap();
    let body = met.get("body").unwrap().as_str().unwrap();
    let sample = parse_prometheus(body)
        .into_iter()
        .find(|(n, _, _)| n == "edgerag_server_rejected_total")
        .map(|(_, _, v)| v)
        .expect("edgerag_server_rejected_total missing from metrics");
    assert_eq!(sample, rejected as f64);
}

#[test]
fn saturated_server_sheds_expired_queries_distinctly() {
    // With a 1µs budget every query's deadline expires while it sits in
    // the admission queue: the worker sheds it with the distinct
    // "deadline exceeded" error (not "overloaded"), counts it
    // server-side, and control ops keep answering.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.deadline_us = 1;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 2, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let mut c = Client::connect(&addr.to_string()).unwrap();
    const N: u64 = 6;
    for i in 0..N {
        let resp = c.query(&format!("doomed query {i} c1")).unwrap();
        let err = resp
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| panic!("expired query must shed, got: {resp}"));
        assert!(err.contains("deadline exceeded"), "{err}");
        assert!(!err.contains("overloaded"), "shed must be distinct from rejection: {err}");
    }

    // Control plane unaffected: ping and stats still serve, and the shed
    // counter matches.
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let srv = stats.get("server").unwrap_or_else(|| panic!("no server block: {stats}"));
    assert_eq!(srv.get("deadline_shed").and_then(|v| v.as_u64()), Some(N), "{srv}");
    assert_eq!(srv.get("deadline_us").and_then(|v| v.as_u64()), Some(1), "{srv}");

    let met = c.call(&Value::object(vec![("op", Value::str("metrics"))])).unwrap();
    let body = met.get("body").unwrap().as_str().unwrap();
    let shed = parse_prometheus(body)
        .into_iter()
        .find(|(n, _, _)| n == "edgerag_server_deadline_shed_total")
        .map(|(_, _, v)| v)
        .expect("edgerag_server_deadline_shed_total missing from metrics");
    assert_eq!(shed, N as f64);
}

#[test]
fn zero_slow_query_threshold_disarms_the_derived_deadline() {
    // Regression: the derived deadline was `4 × slow_query_us`, so
    // `--slow-query-us 0` (keep-all tracing) derived a 0µs budget that
    // shed every query. With both knobs 0 the deadline must disarm —
    // every query serves, nothing sheds.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.trace = true;
    b.retrieval.slow_query_us = 0; // keep-all tracing
    b.retrieval.deadline_us = 0; // derive — must disarm, not derive 0
    assert_eq!(b.retrieval.resolved_deadline_us(), 0);
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 2, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let mut c = Client::connect(&addr.to_string()).unwrap();
    for i in 0..6 {
        let resp = c.query(&format!("keep-all query {i} c1 t0w1")).unwrap();
        assert!(resp.get("error").is_none(), "query {i} shed/errored: {resp}");
        assert!(resp.get("hits").is_some(), "{resp}");
    }
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let srv = stats.get("server").unwrap_or_else(|| panic!("no server block: {stats}"));
    assert_eq!(srv.get("deadline_shed").and_then(|v| v.as_u64()), Some(0), "{srv}");
    assert_eq!(srv.get("deadline_us").and_then(|v| v.as_u64()), Some(0), "{srv}");
}

#[test]
fn reshard_op_round_trips_and_clamps_to_serve_bounds() {
    // The elastic-topology server op: grow over the wire, observe the
    // new shard-stats row count, keep serving, and verify the
    // `--shards-min/--shards-max` clamp.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.shards = 2;
    b.retrieval.shards_min = 1;
    b.retrieval.shards_max = 4;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 2, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    let mut c = Client::connect(&addr.to_string()).unwrap();

    let reshard = |c: &mut Client, n: f64| {
        c.call(&Value::object(vec![
            ("op", Value::str("reshard")),
            ("shards", Value::num(n)),
        ]))
        .unwrap()
    };
    let shard_rows = |c: &mut Client| -> usize {
        c.call(&Value::object(vec![("op", Value::str("shard-stats"))]))
            .unwrap()
            .get("shards")
            .unwrap()
            .as_array()
            .unwrap()
            .len()
    };

    // Grow 2 → 4.
    let grown = reshard(&mut c, 4.0);
    assert_eq!(grown.get("from").and_then(|v| v.as_u64()), Some(2), "{grown}");
    assert_eq!(grown.get("to").and_then(|v| v.as_u64()), Some(4), "{grown}");
    assert_eq!(shard_rows(&mut c), 4);

    // Service continues across the swap.
    let resp = c.query("post-grow query c1 t0w1").unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");

    // Shrink 4 → 1, draining every cluster off the doomed shards.
    let shrunk = reshard(&mut c, 1.0);
    assert_eq!(shrunk.get("to").and_then(|v| v.as_u64()), Some(1), "{shrunk}");
    assert!(
        shrunk.get("migrated").and_then(|v| v.as_u64()).unwrap() > 0,
        "shrink drained nothing: {shrunk}"
    );
    assert_eq!(shard_rows(&mut c), 1);
    let resp = c.query("post-shrink query c1 t0w1").unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");

    // A request beyond --shards-max clamps instead of exploding.
    let clamped = reshard(&mut c, 100.0);
    assert_eq!(clamped.get("requested").and_then(|v| v.as_u64()), Some(100), "{clamped}");
    assert_eq!(clamped.get("to").and_then(|v| v.as_u64()), Some(4), "{clamped}");
    assert_eq!(shard_rows(&mut c), 4);
}

#[test]
fn shutdown_under_load_drains_and_exits_without_helper_connection() {
    // Regression: shutdown used to wake the blocked accept loop by
    // self-connecting a throwaway socket; if that connect raced the
    // listener teardown the server hung. The reactor's wake pipe needs
    // no helper — and a shutdown issued while queries are still queued
    // must drain them (responses flushed, worker jobs finished) before
    // `run()` returns and the WAL checkpoint runs.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    // One worker: the pipelined burst below is still queued when the
    // shutdown lands.
    let server = Server::bind_with_workers("127.0.0.1:0", pipeline, b.embedder(), 1).unwrap();
    let addr = server.local_addr().unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(server.run());
    });

    // Load: pipeline a burst and confirm the server started answering
    // (so every request in the burst is parsed and submitted).
    const BURST: usize = 8;
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut payload = Vec::new();
    for i in 0..BURST {
        payload
            .extend_from_slice(format!("{{\"op\":\"query\",\"text\":\"drain {i} c1\"}}\n").as_bytes());
    }
    w.write_all(&payload).unwrap();
    w.flush().unwrap();
    let first = edgerag::json::parse(&read_line(&mut r)).unwrap();
    assert!(first.get("hits").is_some(), "{first}");

    // Shutdown from a second connection while 7 queries are still
    // queued on the single worker.
    let mut shut = Client::connect(&addr.to_string()).unwrap();
    let ack = shut.call(&Value::object(vec![("op", Value::str("shutdown"))])).unwrap();
    assert_eq!(ack.get("ok").and_then(|v| v.as_bool()), Some(true), "{ack}");

    // The drain completes and `run()` returns — with no helper
    // connection poking the listener awake.
    let run_result = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("server did not exit after shutdown under load");
    run_result.unwrap();

    // Every queued query was answered before exit, then the server
    // closed the connection cleanly.
    for _ in 1..BURST {
        let resp = edgerag::json::parse(&read_line(&mut r)).unwrap();
        assert!(resp.get("hits").is_some(), "{resp}");
    }
    let mut leftover = String::new();
    assert_eq!(r.read_line(&mut leftover).unwrap(), 0, "expected EOF, got: {leftover}");

    // And the listener really is down.
    assert!(
        TcpStream::connect(addr).is_err()
            || TcpStream::connect(addr)
                .and_then(|mut s| {
                    s.write_all(b"{\"op\":\"ping\"}\n")?;
                    let mut buf = String::new();
                    BufReader::new(s).read_line(&mut buf)
                })
                .map(|n| n == 0)
                .unwrap_or(true),
        "server still serving after shutdown"
    );
}
