//! Integration over the TCP serving layer: real sockets, the line-JSON
//! protocol, concurrent clients, online updates through the wire. The
//! stress test drives N parallel clients through interleaved
//! query/insert/stats/remove ops against the worker-pool server.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::json::Value;
use edgerag::server::{Client, Server};
use edgerag::testutil::shared_compute;

fn spawn_server_with_workers(workers: usize) -> (std::net::SocketAddr, usize) {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let n = built.corpus.len();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_workers("127.0.0.1:0", pipeline, b.embedder(), workers).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    (addr, n)
}

fn spawn_server() -> (std::net::SocketAddr, usize) {
    spawn_server_with_workers(4)
}

#[test]
fn batched_server_serves_and_reports_stage_stats() {
    // End-to-end over TCP with the cross-query batch scheduler enabled
    // (the `edgerag serve` default): concurrent clients get correct
    // results and the stats endpoint exposes per-stage scheduler rows.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.batch_window_us = 200;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 4, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..6 {
                let resp = c.query(&format!("batched thread {t} query {i} c1 t0w1")).unwrap();
                assert!(resp.get("hits").is_some(), "{resp}");
                assert!(resp.get("error").is_none(), "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let mut c = Client::connect(&addr.to_string()).unwrap();
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let sched = stats.get("sched").expect("batched server exposes sched stats");
    assert_eq!(
        sched.get("submitted").and_then(|v| v.as_u64()),
        Some(24),
        "{sched}"
    );
    for stage in ["embed", "probe"] {
        let s = sched.get(stage).unwrap_or_else(|| panic!("missing {stage}: {sched}"));
        // Bypassed queries skip the stages; batched ones must balance:
        // submitted items all came back through fused batches.
        let submitted = s.get("submitted").and_then(|v| v.as_u64()).unwrap();
        let batches = s.get("batches").and_then(|v| v.as_u64()).unwrap();
        assert!(batches <= submitted, "{stage}: {s}");
    }
}

/// Minimal Prometheus text-exposition parser: `(name, labels, value)`
/// triples, panicking on any malformed line (bad metric name, missing
/// value, unterminated label set, or a sample with no preceding
/// `# TYPE` for its family).
fn parse_prometheus(body: &str) -> Vec<(String, String, f64)> {
    let mut typed = HashSet::new();
    let mut samples = Vec::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let name = rest.split_whitespace().next().expect("bare # TYPE line");
            typed.insert(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (metric, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("no value on line: {line}"));
        let value: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("bad sample value: {line}"));
        let (name, labels) = match metric.split_once('{') {
            Some((n, l)) => {
                assert!(l.ends_with('}'), "unterminated label set: {line}");
                (n.to_string(), l[..l.len() - 1].to_string())
            }
            None => (metric.to_string(), String::new()),
        };
        assert!(
            !name.is_empty()
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name: {line}"
        );
        let family = name
            .trim_end_matches("_bucket")
            .trim_end_matches("_sum")
            .trim_end_matches("_count");
        assert!(
            typed.contains(&name) || typed.contains(family),
            "sample before its # TYPE line: {line}"
        );
        samples.push((name, labels, value));
    }
    samples
}

#[test]
fn traced_server_exposes_span_trees_and_prometheus_metrics() {
    // The tracing-plane acceptance test: a traced query's span tree
    // covers admission, embedding, the search (per-shard walks + cache
    // outcome) and prefill; a traced insert shows the WAL append; the
    // `metrics` op renders parseable Prometheus text.
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-traceint-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&b.options.state_dir);
    b.retrieval.nprobe = 4;
    b.retrieval.batching = true;
    b.retrieval.trace = true;
    b.retrieval.slow_query_us = 0; // every request crosses the slow threshold
    b.retrieval.wal = true;
    b.options.wal_dir = Some(b.options.state_dir.join("wal"));
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server =
        Server::bind_with_retrieval("127.0.0.1:0", pipeline, b.embedder(), 4, &b.retrieval)
            .unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());
    let mut c = Client::connect(&addr.to_string()).unwrap();

    // A traced query stamps a resolvable trace id into its response…
    let resp = c.query("traced query c1 t0w1").unwrap();
    let qid = resp
        .get("trace_id")
        .and_then(|v| v.as_u64())
        .unwrap_or_else(|| panic!("query response missing trace_id: {resp}"));
    let qt = c
        .call(&Value::object(vec![
            ("op", Value::str("trace")),
            ("id", Value::num(qid as f64)),
        ]))
        .unwrap();
    assert_eq!(qt.get("id").and_then(|v| v.as_u64()), Some(qid), "{qt}");
    let span_names = |t: &Value| -> Vec<String> {
        t.get("spans")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap().to_string())
            .collect()
    };
    let names = span_names(&qt);
    // …whose span tree covers the whole pipeline. A lone query rides the
    // scheduler bypass (inline embedding); under load the same slots are
    // filled by `embed.wait`/`embed.exec` with batch-width attribution.
    for required in [
        "admission",
        "search",
        "shard.walk",
        "cache.outcome",
        "chunk_fetch",
        "prefill",
        "commit",
    ] {
        assert!(names.iter().any(|n| n == required), "span `{required}` missing: {names:?}");
    }
    assert!(
        names.iter().any(|n| n == "embed.exec" || n == "embed.inline"),
        "no embedding span: {names:?}"
    );

    // A traced insert shows the index mutation and the WAL append.
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("traced insert marker vwxyq")),
        ]))
        .unwrap();
    let iid = ins
        .get("trace_id")
        .and_then(|v| v.as_u64())
        .unwrap_or_else(|| panic!("insert response missing trace_id: {ins}"));
    let it = c
        .call(&Value::object(vec![
            ("op", Value::str("trace")),
            ("id", Value::num(iid as f64)),
        ]))
        .unwrap();
    let inames = span_names(&it);
    for required in ["admission", "insert.apply", "wal.append"] {
        assert!(
            inames.iter().any(|n| n == required),
            "insert span `{required}` missing: {inames:?}"
        );
    }

    // The ring listing sees both; threshold 0 fills the slow ring too.
    let listing = c.call(&Value::object(vec![("op", Value::str("trace"))])).unwrap();
    assert_eq!(listing.get("slow_threshold_us").and_then(|v| v.as_u64()), Some(0));
    assert!(!listing.get("recent").unwrap().as_array().unwrap().is_empty());
    assert!(!listing.get("slow").unwrap().as_array().unwrap().is_empty());

    // `stats` exposes the WAL activity block.
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let wal = stats
        .get("wal")
        .unwrap_or_else(|| panic!("stats missing wal block: {stats}"));
    assert!(
        wal.get("frames_appended").and_then(|v| v.as_u64()).unwrap() >= 1,
        "{wal}"
    );

    // `metrics` renders valid Prometheus text exposition.
    let met = c.call(&Value::object(vec![("op", Value::str("metrics"))])).unwrap();
    let body = met.get("body").unwrap().as_str().unwrap();
    let samples = parse_prometheus(body);
    let sample = |name: &str, label_frag: &str| -> f64 {
        samples
            .iter()
            .find(|(n, l, _)| n == name && (label_frag.is_empty() || l.contains(label_frag)))
            .map(|&(_, _, v)| v)
            .unwrap_or_else(|| panic!("metric `{name}` ({label_frag:?}) missing"))
    };
    assert!(sample("edgerag_queries_total", "") >= 1.0);
    assert!(sample("edgerag_wal_frames_appended_total", "") >= 1.0);
    assert!(sample("edgerag_sched_requests_total", "outcome=\"submitted\"") >= 1.0);
    assert!(sample("edgerag_traces_total", "state=\"finished\"") >= 2.0);
    // Histogram consistency: buckets cumulative, +Inf equals _count.
    for family in ["edgerag_retrieval_latency_seconds", "edgerag_ttft_latency_seconds"] {
        let buckets: Vec<f64> = samples
            .iter()
            .filter(|(n, _, _)| n == &format!("{family}_bucket"))
            .map(|&(_, _, v)| v)
            .collect();
        assert!(!buckets.is_empty(), "{family} has no buckets");
        assert!(
            buckets.windows(2).all(|w| w[0] <= w[1]),
            "{family} buckets not cumulative: {buckets:?}"
        );
        assert_eq!(*buckets.last().unwrap(), sample(&format!("{family}_count"), ""));
        assert!(sample(&format!("{family}_sum"), "") > 0.0);
    }
}

#[test]
fn full_protocol_roundtrip() {
    let (addr, corpus_len) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();

    // ping
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));

    // query
    let resp = c.query("c1 c2 some words t0w1 t0w2").unwrap();
    let hits = resp.get("hits").unwrap().as_array().unwrap();
    assert!(!hits.is_empty());
    assert!(resp.get("retrieval_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    // insert + retrieve it
    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("completely unique marker xqzzy document")),
        ]))
        .unwrap();
    let id = ins.get("id").unwrap().as_u64().unwrap();
    assert!(id >= corpus_len as u64);
    let found = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = found
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(ids.contains(&id), "{ids:?} missing {id}");

    // remove + verify gone
    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(id as f64)),
        ]))
        .unwrap();
    assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true));
    let after = c.query("unique marker xqzzy").unwrap();
    let ids: Vec<u64> = after
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(!ids.contains(&id));

    // stats
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    assert!(stats.get("queries").unwrap().as_u64().unwrap() >= 3);

    // bad request surfaces an error, not a disconnect
    let err = c.call(&Value::object(vec![("op", Value::str("nope"))])).unwrap();
    assert!(err.get("error").is_some());
    // connection still usable
    let pong = c.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn query_containing_the_word_shutdown_does_not_kill_the_server() {
    // Regression: shutdown used to substring-match the raw request line.
    let (addr, _) = spawn_server();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let resp = c.query("how do I shutdown my edge device safely \"shutdown\"").unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");
    // The server is still alive: a fresh connection works.
    let mut c2 = Client::connect(&addr.to_string()).unwrap();
    let pong = c2.call(&Value::object(vec![("op", Value::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn concurrent_clients_run_in_parallel_safely() {
    let (addr, _) = spawn_server();
    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..5 {
                let resp = c.query(&format!("thread {t} query {i} c3 c4")).unwrap();
                assert!(resp.get("hits").is_some(), "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn stress_parallel_clients_interleave_query_insert_stats() {
    // The tentpole acceptance test: N parallel clients mixing reads
    // (query/stats) and writes (insert/remove) must finish without
    // deadlock, allocate globally unique ids, and observe monotone
    // metrics counters.
    let (addr, corpus_len) = spawn_server_with_workers(4);
    const THREADS: usize = 8;
    const OPS: usize = 16;

    let inserted: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let addr = addr.to_string();
        let inserted = inserted.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let mut my_ids = Vec::new();
            let mut last_queries = 0u64;
            for i in 0..OPS {
                match i % 4 {
                    // reads dominate, like real traffic
                    0 | 1 => {
                        let resp = c
                            .query(&format!("stress thread {t} op {i} c1 t0w1"))
                            .unwrap();
                        assert!(resp.get("hits").is_some(), "{resp}");
                        assert!(resp.get("error").is_none(), "{resp}");
                    }
                    2 => {
                        let text = format!("stress doc from thread {t} op {i} marker zq{t}x{i}");
                        let ins = c
                            .call(&Value::object(vec![
                                ("op", Value::str("insert")),
                                ("text", Value::str(text)),
                            ]))
                            .unwrap();
                        let id = ins.get("id").and_then(|v| v.as_u64()).unwrap_or_else(|| {
                            panic!("insert failed: {ins}")
                        });
                        my_ids.push(id);
                    }
                    _ => {
                        let stats = c
                            .call(&Value::object(vec![("op", Value::str("stats"))]))
                            .unwrap();
                        let q = stats.get("queries").and_then(|v| v.as_u64()).unwrap();
                        assert!(
                            q >= last_queries,
                            "queries counter went backwards: {q} < {last_queries}"
                        );
                        last_queries = q;
                    }
                }
            }
            // Remove one of our docs through the wire, too.
            if let Some(&id) = my_ids.first() {
                let rem = c
                    .call(&Value::object(vec![
                        ("op", Value::str("remove")),
                        ("id", Value::num(id as f64)),
                    ]))
                    .unwrap();
                assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true), "{rem}");
                my_ids.remove(0);
            }
            inserted.lock().unwrap().extend(my_ids);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Ids are globally unique and allocated past the corpus.
    let ids = inserted.lock().unwrap().clone();
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(unique.len(), ids.len(), "duplicate ids: {ids:?}");
    assert!(ids.iter().all(|&id| id >= corpus_len as u64));

    // Surviving inserts are retrievable; the query counter matches the
    // exact number of query ops served.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let t0_doc = c.query("stress doc thread 0 marker zq0x6").unwrap();
    assert!(t0_doc.get("hits").is_some());
    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let total_queries = stats.get("queries").and_then(|v| v.as_u64()).unwrap();
    let expected = (THREADS * OPS / 2) as u64 + 1; // i%4 ∈ {0,1} per thread + this probe
    assert_eq!(total_queries, expected);
}
