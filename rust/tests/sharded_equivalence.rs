//! Sharded-vs-unsharded equivalence and shard-scoped concurrency at the
//! system level: the same corpus and queries must produce identical
//! top-k results (ids *and* scores) and identical per-cluster cache
//! admissions for `shards = 1` vs `shards = 4`, and an online insert
//! must overlap with queries/readers of other shards instead of
//! stalling the whole index.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::index::{EdgeIndex, ShardedEdgeIndex, VectorIndex};
use edgerag::json::Value;
use edgerag::server::{Client, Server};
use edgerag::testutil::shared_compute;

fn builder(shards: usize, tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    // Per-test blob-store root: tests in this binary run in parallel and
    // must not clear each other's stores.
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-eqv-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    b
}

/// Shard count for the sharded side of each test: `EDGERAG_TEST_SHARDS`
/// pins it (the CI churn matrix re-runs this suite across {1, 4} — 1 is
/// the degenerate single-shard-vs-single-shard sanity leg), default 4.
fn sharded_count() -> usize {
    match std::env::var("EDGERAG_TEST_SHARDS") {
        Ok(v) => v.parse().expect("EDGERAG_TEST_SHARDS must be an integer"),
        Err(_) => 4,
    }
}

#[test]
fn sharded_four_matches_unsharded_exactly() {
    let k = sharded_count();
    let b1 = builder(1, "eq1");
    let b4 = builder(k, "eq4");
    let built1 = b1.build_dataset(&DatasetProfile::tiny()).unwrap();
    let built4 = b4.build_dataset(&DatasetProfile::tiny()).unwrap();

    let (mut one, _mem1) = b1.index(&built1, IndexKind::EdgeRag).unwrap();
    let (mut four, _mem4) = b4.index(&built4, IndexKind::EdgeRag).unwrap();
    // shards=1 must take the plain single-index path; shards>1 the
    // sharded one.
    assert!(one.as_any().downcast_ref::<EdgeIndex>().is_some());
    if k > 1 {
        let sharded = four
            .as_any()
            .downcast_ref::<ShardedEdgeIndex>()
            .expect("shards>1 builds a ShardedEdgeIndex");
        assert_eq!(sharded.shards(), k);
    }

    // Pin both thresholds to 0 (admit everything): the per-shard
    // feedback controllers see different miss streams, so leaving them
    // adaptive could legitimately diverge the admission gate — the
    // equivalence claim is about the retrieval results and the admitted
    // cluster set under an identical policy.
    one.as_any_mut()
        .downcast_mut::<EdgeIndex>()
        .unwrap()
        .pin_threshold(0.0);
    four.pin_threshold(0.0);

    let embedder = b1.embedder();
    for (i, q) in built1.workload.queries.iter().take(32).enumerate() {
        let emb = embedder.embed_one(&q.text).unwrap();
        let a = one.search(&emb, 5).unwrap();
        let b = four.search(&emb, 5).unwrap();
        // Bit-identical hits: same chunk ids, same f32 scores, same order.
        assert_eq!(a.hits, b.hits, "query {i} hits diverged");
        // Same probes, as global cluster ids, in the same order.
        assert_eq!(a.probed, b.probed, "query {i} probes diverged");
        // Same materialization decisions.
        assert_eq!(a.events.generated, b.events.generated, "query {i}");
        assert_eq!(a.events.loaded, b.events.loaded, "query {i}");
        assert_eq!(a.events.cache_hits, b.events.cache_hits, "query {i}");
        one.commit(&a.intents, a.ledger.retrieval());
        four.commit(&b.intents, b.ledger.retrieval());
    }

    // Identical per-cluster cache admissions: the resident sets match
    // exactly (shard-local ids mapped back to global ones), and so do
    // the insertion counters.
    let edge = one.as_any().downcast_ref::<EdgeIndex>().unwrap();
    assert_eq!(edge.cached_clusters(), four.cached_clusters());
    let s1 = edge.cache_stats().unwrap();
    let s4 = four.cache_stats().unwrap();
    assert_eq!(s1.insertions, s4.insertions);
    assert_eq!(s1.hits, s4.hits);
    assert_eq!(s1.misses, s4.misses);
}

#[test]
fn insert_overlaps_queries_to_other_shards() {
    let k = sharded_count();
    if k < 2 {
        eprintln!("skipping: shard-overlap semantics need at least 2 shards");
        return;
    }
    let b = builder(k, "overlap");
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());
    let embedder = b.embedder();

    // Directed overlap: pin down which shard the insert will route to,
    // then hold a *read* lease on a different shard (as a concurrent
    // query would) while the insert runs on another thread. It must
    // complete — on the old single-lease design this pattern deadlocked
    // by construction (insert required the exclusive engine lease, which
    // can't be granted while any read lease is out).
    let text = "directed overlap marker document zzdirected overlap";
    let emb = embedder.embed_one(text).unwrap();
    let index = engine.index();
    let sharded = index
        .as_any()
        .downcast_ref::<ShardedEdgeIndex>()
        .expect("serve path builds the sharded index");
    let target = sharded.route(&emb).unwrap();
    let other = (target + 1) % sharded.shards();
    let routed_shard = sharded.with_shard(other, |_reader| {
        let (tx, rx) = mpsc::channel();
        let engine2 = engine.clone();
        let text2 = text.to_string();
        std::thread::spawn(move || {
            let _ = tx.send(engine2.insert(&text2));
        });
        let (id, cluster) = rx
            .recv_timeout(Duration::from_secs(20))
            .expect("insert must not block on another shard's read lease")
            .expect("insert succeeds");
        assert_eq!(engine.texts().get(id).as_deref(), Some(text));
        sharded.shard_of(cluster)
    });
    assert_eq!(routed_shard, target, "insert landed on its routed shard");
    drop(index);

    // Churn: queries hammer the engine while inserts land on whichever
    // shards their embeddings route to.
    let base_texts: Vec<String> = (0..12)
        .map(|i| format!("concurrent sharded insert {i} marker zzins{i}q"))
        .collect();
    std::thread::scope(|scope| {
        for t in 0..2 {
            let engine = &engine;
            let built = &built;
            scope.spawn(move || {
                for (i, q) in built.workload.queries.iter().take(20).enumerate() {
                    let out = engine.handle(&q.text).unwrap();
                    assert!(!out.hits.is_empty(), "thread {t} query {i} empty");
                }
            });
        }
        let engine = &engine;
        let texts = &base_texts;
        scope.spawn(move || {
            for text in texts {
                engine.insert(text).unwrap();
            }
        });
    });

    // Every insert is retrievable through the normal serving path.
    for text in &base_texts {
        let out = engine.handle(text).unwrap();
        let expect = engine.texts().len(); // texts store includes them all
        assert!(expect > built.corpus.len());
        assert!(
            out.hits.iter().any(|&(id, _)| id >= built.corpus.len() as u32),
            "inserted doc not retrieved for {text:?}: {:?}",
            out.hits
        );
    }

    // Per-shard accounting: 13 inserts total (1 directed + 12 churned),
    // attributed to their owning shards.
    let index = engine.index();
    let sharded = index.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
    let stats = sharded.shard_stats();
    assert_eq!(stats.len(), k);
    let total_inserts: u64 = stats.iter().map(|s| s.inserts).sum();
    assert_eq!(total_inserts, 13);
    let total_probes: u64 = stats.iter().map(|s| s.probes).sum();
    assert!(total_probes > 0, "probes must be attributed to shards");
}

#[test]
fn sharded_server_serves_inserts_and_per_shard_stats() {
    // End-to-end over TCP with the sharded index `serve` defaults to.
    let k = sharded_count();
    if k < 2 {
        eprintln!("skipping: per-shard stats rows need a sharded index");
        return;
    }
    let b = builder(k, "server");
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let pipeline = b.pipeline(&built, IndexKind::EdgeRag).unwrap();
    let server = Server::bind_with_workers("127.0.0.1:0", pipeline, b.embedder(), 4).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let mut c = Client::connect(&addr.to_string()).unwrap();
    let resp = c.query("c1 c2 words t0w1 t0w2").unwrap();
    assert!(resp.get("hits").is_some(), "{resp}");

    let ins = c
        .call(&Value::object(vec![
            ("op", Value::str("insert")),
            ("text", Value::str("sharded server marker xqshard doc")),
        ]))
        .unwrap();
    let id = ins.get("id").and_then(|v| v.as_u64()).expect("insert id");
    let found = c.query("sharded server marker xqshard").unwrap();
    let ids: Vec<u64> = found
        .get("hits")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
        .collect();
    assert!(ids.contains(&id), "{ids:?} missing {id}");

    let stats = c.call(&Value::object(vec![("op", Value::str("stats"))])).unwrap();
    let shards = stats
        .get("shards")
        .and_then(|v| v.as_array())
        .expect("sharded stats expose per-shard rows");
    assert_eq!(shards.len(), k);
    let inserts: u64 = shards
        .iter()
        .map(|s| s.get("inserts").and_then(|v| v.as_u64()).unwrap())
        .sum();
    assert_eq!(inserts, 1);
    let probes: u64 = shards
        .iter()
        .map(|s| s.get("probes").and_then(|v| v.as_u64()).unwrap())
        .sum();
    assert!(probes > 0);

    let rem = c
        .call(&Value::object(vec![
            ("op", Value::str("remove")),
            ("id", Value::num(id as f64)),
        ]))
        .unwrap();
    assert_eq!(rem.get("removed").and_then(|v| v.as_bool()), Some(true), "{rem}");

    // The dedicated per-shard load view: same rows as `stats.shards`,
    // including the rebalancer's row-count load measure.
    let ss = c
        .call(&Value::object(vec![("op", Value::str("shard-stats"))]))
        .unwrap();
    let rows = ss
        .get("shards")
        .and_then(|v| v.as_array())
        .expect("shard-stats returns per-shard rows");
    assert_eq!(rows.len(), k);
    let total_rows: u64 = rows
        .iter()
        .map(|s| s.get("rows").and_then(|v| v.as_u64()).unwrap())
        .sum();
    assert!(total_rows > 0, "per-shard row loads exposed");

    // An explicit rebalance round over the wire: a full report comes
    // back and the server keeps serving afterwards.
    let rb = c
        .call(&Value::object(vec![("op", Value::str("rebalance"))]))
        .unwrap();
    let before = rb.get("spread_before").and_then(|v| v.as_u64()).unwrap();
    let after = rb.get("spread_after").and_then(|v| v.as_u64()).unwrap();
    assert!(after <= before, "{rb}");
    assert!(rb.get("migrated").is_some(), "{rb}");
    let resp = c.query("c1 c2 words t0w1 t0w2").unwrap();
    assert!(resp.get("hits").is_some(), "server serves after rebalance: {resp}");
}
