//! Crash-point fault matrix for the structural write-ahead log
//! (`rust/src/storage/wal.rs`), in the style of `merge_faults.rs`: arm
//! one injected fault, drive the op that trips it, then prove the index
//! **recovers to a `verify_integrity`-green, oracle-equal state** from
//! whatever survived on disk.
//!
//! Crash points exercised, one per test:
//!
//! 1. **Torn tail record** — the log ends mid-frame (power loss during
//!    an append): recovery truncates back to the last good record and
//!    the index equals the oracle of the surviving prefix; appends
//!    continue at the next sequence number.
//! 2. **Corrupt byte mid-log** — a flipped byte fails the frame
//!    checksum; everything from that record on is dropped.
//! 3. **Append fault before the write** — the op aborts with neither a
//!    record nor a mutation; log and index agree that nothing happened,
//!    and the retry goes through.
//! 4. **Crash between append and mutation (insert)** — the record is
//!    durable, the mutation never ran: the append is the commit point,
//!    so recovery *applies* the op.
//! 5. **Crash between append and mutation (removal)** — same, for the
//!    removal record class.
//! 6. **Crash between append and mutation (migration)** — same, for the
//!    rebalancer's placement records: the recovered index completes the
//!    recorded move.
//! 7. **Crash mid-snapshot** — the staged temp snapshot is discarded;
//!    the old snapshot + full log still hold every record.
//! 8. **Crash between snapshot publication and log truncation** — every
//!    record briefly exists in two places; recovery skips the covered
//!    log records (no double-apply) and completes the truncation.

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::{BuiltDataset, SystemBuilder};
use edgerag::index::{EdgeIndex, ShardedEdgeIndex, SharedMemory, VectorIndex};
use edgerag::storage::{WalOp, WriteAheadLog};
use edgerag::testutil::shared_compute;
use std::sync::Arc;

fn builder(tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-wfault-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = 2;
    b.retrieval.wal = true;
    b.retrieval.snapshot_interval_ops = 0; // rotation only via checkpoint
    b.options.wal_dir = Some(b.options.state_dir.join("wal"));
    b
}

struct Fx {
    b: SystemBuilder,
    built: BuiltDataset,
    idx: Option<Box<dyn VectorIndex>>,
    // Keep every generation's shared-memory handle alive for the
    // index's lifetime (same idiom as merge_faults' `_mem`).
    _mems: Vec<SharedMemory>,
    n_chunks: u32,
}

impl Fx {
    fn sharded(&self) -> &ShardedEdgeIndex {
        self.idx
            .as_ref()
            .unwrap()
            .as_any()
            .downcast_ref::<ShardedEdgeIndex>()
            .unwrap()
    }

    fn wal(&self) -> Arc<WriteAheadLog> {
        self.sharded().wal().unwrap().clone()
    }

    /// Simulated crash + restart: drop the index (no checkpoint — the
    /// on-disk snapshot + log is all that survives), then rebuild
    /// through the builder's recovery path.
    fn crash_and_recover(&mut self) {
        self.idx = None;
        let (idx, mem) = self.b.index(&self.built, IndexKind::EdgeRag).unwrap();
        self.idx = Some(idx);
        self._mems.push(mem);
    }

    /// The deterministic (id → payload) scheme fault tests insert with.
    fn doc(&self, id: u32) -> (String, Vec<f32>) {
        let text = format!("wal fault doc {id} marker zzwalf{id}");
        let emb = self.b.embedder().embed_one(&text).unwrap();
        (text, emb)
    }

    fn insert(&self, id: u32) -> anyhow::Result<u32> {
        let (text, emb) = self.doc(id);
        self.sharded().insert_chunk(id, &text, &emb)
    }

    /// A chunk's own text must retrieve it as the top hit.
    fn assert_serving(&self, text: &str, id: u32) {
        let emb = self.b.embedder().embed_one(text).unwrap();
        let out = self.sharded().search(&emb, 3).unwrap();
        assert_eq!(out.hits[0].0, id, "chunk {id} not served: {:?}", out.hits);
    }
}

fn fixture(tag: &str) -> Fx {
    let b = builder(tag);
    let _ = std::fs::remove_dir_all(b.options.wal_dir.as_ref().unwrap());
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (idx, mem) = b.index(&built, IndexKind::EdgeRag).unwrap();
    let n_chunks = built.corpus.len() as u32;
    Fx {
        b,
        built,
        idx: Some(idx),
        _mems: vec![mem],
        n_chunks,
    }
}

/// Assert the recovered index equals a fresh single-shard oracle that
/// applied `ops` through the ordinary public update paths: invariant
/// suite, surviving cluster count, membership of every id in play, and
/// a bit-compared search battery.
fn assert_matches_oracle(fx: &Fx, tag: &str, ops: &[WalOp]) {
    let mut b_o = builder(&format!("{tag}-oracle"));
    b_o.retrieval.shards = 1;
    b_o.retrieval.wal = false;
    let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
    let (mut oracle, _m) = b_o.index(&built_o, IndexKind::EdgeRag).unwrap();
    let mut ids: Vec<u32> = (0..fx.n_chunks).collect();
    for op in ops {
        match op {
            WalOp::Insert { id, text, emb } => {
                oracle.insert_chunk(*id, text, emb).unwrap();
                ids.push(*id);
            }
            WalOp::Remove { id } => {
                assert!(oracle.remove_chunk(*id).unwrap());
            }
            op => unreachable!("oracle ops are inserts/removes only, got {op:?}"),
        }
    }
    let oracle_edge = oracle.as_any().downcast_ref::<EdgeIndex>().unwrap();

    let sharded = fx.sharded();
    sharded.verify_integrity().unwrap();
    assert_eq!(
        sharded.active_clusters(),
        oracle_edge.active_clusters(),
        "{tag}: active-cluster sets diverged"
    );
    for id in ids {
        assert_eq!(
            sharded.cluster_of(id),
            oracle_edge.cluster_of(id),
            "{tag}: chunk {id} routed differently"
        );
    }
    let embedder = fx.b.embedder();
    for q in fx.built.workload.queries.iter().take(8) {
        let emb = embedder.embed_one(&q.text).unwrap();
        let a = oracle.search(&emb, 5).unwrap();
        let s = sharded.search(&emb, 5).unwrap();
        assert_eq!(a.hits, s.hits, "{tag}: hits diverged");
        assert_eq!(a.probed, s.probed, "{tag}: probed sets diverged");
        assert_eq!(a.ledger.total(), s.ledger.total(), "{tag}: modeled latency diverged");
    }
}

/// Find the byte offset of `needle` (a record payload) inside the log.
fn find_payload(log: &[u8], needle: &[u8]) -> usize {
    log.windows(needle.len())
        .position(|w| w == needle)
        .expect("record payload present in the log")
}

#[test]
fn torn_tail_record_recovers_to_the_log_prefix() {
    let mut fx = fixture("torn");
    let base = fx.n_chunks;
    for i in 0..3 {
        fx.insert(base + i).unwrap();
    }
    fx.sharded().verify_integrity().unwrap();
    let log_path = fx.wal().log_path();
    fx.idx = None; // crash

    // Tear the log mid-way through the third insert's frame: its header
    // survives, its payload does not.
    let bytes = std::fs::read(&log_path).unwrap();
    let (text2, emb2) = fx.doc(base + 2);
    let payload = WalOp::Insert { id: base + 2, text: text2, emb: emb2 }.encode();
    let pos = find_payload(&bytes, &payload);
    std::fs::OpenOptions::new()
        .write(true)
        .open(&log_path)
        .unwrap()
        .set_len((pos + payload.len() / 2) as u64)
        .unwrap();

    let (idx, mem) = fx.b.index(&fx.built, IndexKind::EdgeRag).unwrap();
    fx.idx = Some(idx);
    fx._mems.push(mem);

    // The torn insert is gone; the two durable ones survived exactly.
    assert_eq!(fx.sharded().cluster_of(base + 2), None, "torn record must not replay");
    let mut surviving = Vec::new();
    for i in 0..2 {
        let (text, emb) = fx.doc(base + i);
        assert!(fx.sharded().cluster_of(base + i).is_some(), "durable insert {i} lost");
        fx.assert_serving(&text, base + i);
        surviving.push(WalOp::Insert { id: base + i, text, emb });
    }
    assert_matches_oracle(&fx, "torn", &surviving);

    // Appends continue past the truncated tail: re-issuing the lost op
    // survives the next crash.
    fx.insert(base + 2).unwrap();
    fx.crash_and_recover();
    let (text2, _) = fx.doc(base + 2);
    fx.assert_serving(&text2, base + 2);
    fx.sharded().verify_integrity().unwrap();
}

#[test]
fn corrupt_byte_mid_log_drops_the_suffix() {
    let mut fx = fixture("corrupt");
    let base = fx.n_chunks;
    for i in 0..3 {
        fx.insert(base + i).unwrap();
    }
    let log_path = fx.wal().log_path();
    fx.idx = None; // crash

    // Flip one byte inside the *second* insert's payload: the frame
    // checksum rejects it, and recovery must stop there — replaying a
    // corrupted record would be worse than losing its suffix.
    let mut bytes = std::fs::read(&log_path).unwrap();
    let (text1, emb1) = fx.doc(base + 1);
    let payload = WalOp::Insert { id: base + 1, text: text1, emb: emb1 }.encode();
    let pos = find_payload(&bytes, &payload);
    bytes[pos + payload.len() / 2] ^= 0xFF;
    std::fs::write(&log_path, &bytes).unwrap();

    let (idx, mem) = fx.b.index(&fx.built, IndexKind::EdgeRag).unwrap();
    fx.idx = Some(idx);
    fx._mems.push(mem);

    let (text0, emb0) = fx.doc(base);
    assert!(fx.sharded().cluster_of(base).is_some(), "record before the corruption lost");
    assert_eq!(fx.sharded().cluster_of(base + 1), None, "corrupt record replayed");
    assert_eq!(fx.sharded().cluster_of(base + 2), None, "record after the corruption replayed");
    fx.assert_serving(&text0, base);
    assert_matches_oracle(
        &fx,
        "corrupt",
        &[WalOp::Insert { id: base, text: text0, emb: emb0 }],
    );
}

#[test]
fn append_fault_before_write_leaves_log_and_index_agreed() {
    let mut fx = fixture("prefault");
    let base = fx.n_chunks;

    fx.wal().inject_append_failures(1);
    let err = fx.insert(base);
    assert!(err.is_err(), "injected append fault must surface");
    assert_eq!(fx.sharded().cluster_of(base), None, "faulted insert must not mutate");
    fx.sharded().verify_integrity().unwrap();

    // Retry goes through; recovery sees exactly one copy.
    fx.insert(base).unwrap();
    fx.crash_and_recover();
    let (text, emb) = fx.doc(base);
    fx.assert_serving(&text, base);
    assert_matches_oracle(&fx, "prefault", &[WalOp::Insert { id: base, text, emb }]);
}

#[test]
fn crash_between_append_and_mutation_replays_the_insert() {
    let mut fx = fixture("postins");
    let base = fx.n_chunks;

    // The record lands durably, then the "process dies" before the
    // in-memory mutation: the append is the commit point, so the
    // recovered index — unlike the pre-crash one — contains the chunk.
    fx.wal().inject_post_append_failures(1);
    let err = fx.insert(base);
    assert!(err.is_err(), "injected post-append fault must surface");
    assert_eq!(
        fx.sharded().cluster_of(base),
        None,
        "the op must abort pre-mutation — the pre-crash index never sees it"
    );
    fx.sharded().verify_integrity().unwrap();

    fx.crash_and_recover();
    let (text, emb) = fx.doc(base);
    assert!(
        fx.sharded().cluster_of(base).is_some(),
        "recovery must apply the durably logged insert"
    );
    fx.assert_serving(&text, base);
    assert_matches_oracle(&fx, "postins", &[WalOp::Insert { id: base, text, emb }]);
}

#[test]
fn crash_between_append_and_mutation_replays_the_removal() {
    let mut fx = fixture("postrem");
    let victim = 0u32;
    let cluster = fx.sharded().cluster_of(victim).expect("corpus chunk 0 is routed");

    fx.wal().inject_post_append_failures(1);
    let err = fx.sharded().remove_chunk(victim);
    assert!(err.is_err(), "injected post-append fault must surface");
    assert_eq!(
        fx.sharded().cluster_of(victim),
        Some(cluster),
        "the removal must abort pre-mutation"
    );
    fx.sharded().verify_integrity().unwrap();

    fx.crash_and_recover();
    assert_eq!(
        fx.sharded().cluster_of(victim),
        None,
        "recovery must apply the durably logged removal"
    );
    assert_matches_oracle(&fx, "postrem", &[WalOp::Remove { id: victim }]);
}

#[test]
fn crash_between_append_and_mutation_replays_the_migration() {
    let mut fx = fixture("postmig");
    let sharded = fx.sharded();
    let g = sharded.cluster_loads()[0]
        .first()
        .expect("shard 0 owns a cluster")
        .global;
    let src = sharded.shard_of(g);
    let dest = 1 - src;

    fx.wal().inject_post_append_failures(1);
    let err = sharded.migrate_cluster(g, dest);
    assert!(err.is_err(), "injected post-append fault must surface");
    assert_eq!(
        sharded.shard_of(g),
        src,
        "the migration must abort with both shards untouched"
    );
    sharded.verify_integrity().unwrap();

    fx.crash_and_recover();
    assert_eq!(
        fx.sharded().shard_of(g),
        dest,
        "recovery must complete the durably logged move"
    );
    fx.sharded().verify_integrity().unwrap();
    // Placement changed; structure didn't — the oracle comparison pins
    // that the replayed migration perturbed nothing observable.
    assert_matches_oracle(&fx, "postmig", &[]);
}

#[test]
fn crash_mid_snapshot_loses_nothing() {
    let mut fx = fixture("midsnap");
    let base = fx.n_chunks;
    let mut ops = Vec::new();
    for i in 0..4 {
        fx.insert(base + i).unwrap();
        let (text, emb) = fx.doc(base + i);
        ops.push(WalOp::Insert { id: base + i, text, emb });
    }

    // Die after staging the temp snapshot, before the atomic rename.
    let wal = fx.wal();
    wal.inject_rotate_failures(1);
    let err = fx.idx.as_ref().unwrap().wal_checkpoint();
    assert!(err.is_err(), "injected rotate fault must surface");
    assert!(wal.snapshot_tmp_path().exists(), "temp snapshot staged");
    assert!(!wal.snapshot_path().exists(), "snapshot must not be published");
    drop(wal);

    // Recovery discards the temp and replays the intact log.
    fx.crash_and_recover();
    assert!(!fx.wal().snapshot_tmp_path().exists(), "stale temp must be deleted");
    for i in 0..4 {
        let (text, _) = fx.doc(base + i);
        fx.assert_serving(&text, base + i);
    }
    assert_matches_oracle(&fx, "midsnap", &ops);
}

#[test]
fn crash_between_snapshot_and_truncation_never_double_applies() {
    let mut fx = fixture("trunc");
    let base = fx.n_chunks;
    let mut ops = Vec::new();
    for i in 0..4 {
        fx.insert(base + i).unwrap();
        let (text, emb) = fx.doc(base + i);
        ops.push(WalOp::Insert { id: base + i, text, emb });
    }

    // Die after the snapshot rename, before the log truncation: every
    // record now exists in both files.
    let wal = fx.wal();
    wal.inject_truncate_failures(1);
    let err = fx.idx.as_ref().unwrap().wal_checkpoint();
    assert!(err.is_err(), "injected truncate fault must surface");
    assert!(wal.snapshot_path().exists(), "snapshot was published");
    assert!(
        std::fs::metadata(wal.log_path()).unwrap().len() > 0,
        "log not yet truncated"
    );
    let log_path = wal.log_path();
    drop(wal);

    // Recovery must skip the covered log records — a double-applied
    // insert would bail on the duplicate id and recovery itself would
    // fail — and complete the interrupted truncation.
    fx.crash_and_recover();
    assert_eq!(
        std::fs::metadata(&log_path).unwrap().len(),
        0,
        "recovery completes the interrupted truncation"
    );
    for i in 0..4 {
        let (text, _) = fx.doc(base + i);
        fx.assert_serving(&text, base + i);
    }
    assert_matches_oracle(&fx, "trunc", &ops);
}
