//! Randomized churn suite for the online cross-shard rebalancer
//! (`rust/src/index/rebalance.rs`).
//!
//! Four layers of evidence, all seeded through
//! `edgerag::testutil::test_seed` (`EDGERAG_TEST_SEED` overrides; the
//! effective seed is printed so CI flakes are reproducible):
//!
//! 1. **Live-migration equivalence** — 8 threads search continuously
//!    (through the cross-query batch scheduler when
//!    `EDGERAG_TEST_BATCHING` enables it) while a driver migrates
//!    clusters between shards and runs rebalance rounds; every single
//!    search result must be bit-identical to a single-shard oracle.
//! 2. **Sequential randomized churn** — a seeded interleaving of
//!    insert / remove / search / rebalance ops replayed against both the
//!    sharded index and a single-shard oracle, asserting bit-identical
//!    searches (hits, probes, events, modeled latency), identical
//!    cluster-id allocation, and the full cross-shard invariant set
//!    after every rebalance round.
//! 3. **Concurrent churn smoke** — 8 threads mixing all op kinds with
//!    periodic auto-rebalance enabled; nothing may deadlock, lose a
//!    chunk, or break an invariant.
//! 4. **Merge-heavy churn** — a removal-dominant op mix driven through
//!    the full engine (and the batch scheduler on the batching legs)
//!    that drains clusters through `MERGE_THRESHOLD` continuously,
//!    asserting oracle equality at shards ∈ {1, 2, 4, 8}.
//!
//! The op space is **unrestricted**: removals deliberately drain
//! clusters through the merge threshold to empty. Merges route to the
//! *global* nearest-neighbour centroid (cross-shard when the victim
//! lives elsewhere — the composed migrate-then-merge), so every op kind,
//! merges included, is bit-comparable to the single-shard oracle. (The
//! historical steering that kept removals above `MERGE_THRESHOLD + 1` —
//! the last documented oracle divergence — is gone.)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::data::Rng;
use edgerag::index::{EdgeIndex, ShardedEdgeIndex, VectorIndex};
use edgerag::sched::{BatchScheduler, SchedConfig};
use edgerag::testutil::{shared_compute, test_seed};

fn builder(shards: usize, tag: &str) -> SystemBuilder {
    let mut b = SystemBuilder::new(shared_compute(), DeviceProfile::jetson_orin_nano());
    b.options.cache_dir = None;
    // Per-test blob-store root: tests in this binary run in parallel and
    // must not clear each other's stores.
    b.options.state_dir =
        std::env::temp_dir().join(format!("edgerag-churn-{tag}-{}", std::process::id()));
    b.retrieval.nprobe = 4;
    b.retrieval.shards = shards;
    b
}

/// Shard counts under test: `EDGERAG_TEST_SHARDS=N` pins one (the CI
/// matrix); the default covers the degenerate single shard and 4 shards.
fn shard_counts() -> Vec<usize> {
    match std::env::var("EDGERAG_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("EDGERAG_TEST_SHARDS must be an integer")],
        Err(_) => vec![1, 4],
    }
}

/// Shard counts for the merge-routing suites — the "bit-identical at any
/// N" acceptance sweep. `EDGERAG_TEST_SHARDS` pins one (the CI matrix).
fn merge_shard_counts() -> Vec<usize> {
    match std::env::var("EDGERAG_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("EDGERAG_TEST_SHARDS must be an integer")],
        Err(_) => vec![1, 2, 4, 8],
    }
}

/// Pick a removal victim: half the time a chunk from the currently
/// smallest non-empty cluster of the oracle (deterministically draining
/// clusters through `MERGE_THRESHOLD` to empty, so merges fire
/// constantly), otherwise a uniformly random alive chunk. Both replicas
/// replay the identical choice.
fn removal_victim(rng: &mut Rng, oracle: &EdgeIndex, alive: &[u32]) -> u32 {
    if rng.below(2) == 0 {
        oracle
            .clusters()
            .clusters
            .iter()
            .filter(|m| !m.is_empty())
            .min_by_key(|m| (m.len(), m.id))
            .map(|m| m.chunk_ids[0])
            .expect("alive chunks imply a non-empty cluster")
    } else {
        alive[rng.below(alive.len())]
    }
}

/// Batching modes under test: `EDGERAG_TEST_BATCHING=true|false` pins
/// one (the CI matrix); default covers both.
fn batching_modes() -> Vec<bool> {
    match std::env::var("EDGERAG_TEST_BATCHING") {
        Ok(v) => match v.as_str() {
            "true" => vec![true],
            "false" => vec![false],
            other => panic!("EDGERAG_TEST_BATCHING must be true or false, got `{other}`"),
        },
        Err(_) => vec![false, true],
    }
}

/// Batched bit-equivalence only holds on the reference backend (compiled
/// PJRT graphs lower per batch shape) — same qualifier as
/// `sched_equivalence.rs`.
fn reference_backend() -> bool {
    if shared_compute().backend_name() == "pjrt" {
        eprintln!(
            "skipping batched leg: bit-equivalence is asserted on the reference backend only"
        );
        return false;
    }
    true
}

#[test]
fn concurrent_searches_during_live_migrations_match_oracle() {
    // The acceptance property: while clusters migrate between shards,
    // every concurrently served search is bit-identical to a
    // single-shard oracle — at 4 shards, with the batch scheduler on.
    let seed = test_seed(0x11FE);
    for shards in shard_counts() {
        for batching in batching_modes() {
            if batching && !reference_backend() {
                continue;
            }
            let tag = format!("live-{shards}-{batching}");
            let b_o = builder(1, &format!("{tag}-oracle"));
            let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
            let oracle = b_o.pipeline(&built_o, IndexKind::EdgeRag).unwrap();
            oracle.index_mut().pin_threshold(0.0);

            let b = builder(shards, &tag);
            let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
            let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());
            engine.index_mut().pin_threshold(0.0);
            let sched = batching.then(|| {
                BatchScheduler::new(
                    engine.clone(),
                    SchedConfig {
                        batch_window_us: 300,
                        max_inflight: 0,
                        bypass: true,
                    },
                )
            });

            let queries: Vec<String> = built
                .workload
                .queries
                .iter()
                .take(16)
                .map(|q| q.text.clone())
                .collect();
            let expect: Vec<Vec<(u32, f32)>> = queries
                .iter()
                .map(|q| oracle.handle(q).unwrap().hits)
                .collect();

            let done = AtomicBool::new(false);
            std::thread::scope(|scope| {
                // 8 searcher threads hammer the engine while migrations
                // run; each asserts every result against the oracle.
                for t in 0..8usize {
                    let engine = &engine;
                    let sched = &sched;
                    let queries = &queries;
                    let expect = &expect;
                    let done = &done;
                    scope.spawn(move || {
                        let mut rng = Rng::new(seed ^ (t as u64 + 1));
                        for round in 0..40 {
                            let i = rng.below(queries.len());
                            let out = match sched {
                                Some(s) => s.handle(&queries[i]).unwrap(),
                                None => engine.handle(&queries[i]).unwrap(),
                            };
                            assert_eq!(
                                out.hits, expect[i],
                                "thread {t} round {round} query {i} diverged mid-migration"
                            );
                        }
                        done.store(true, Ordering::Release);
                    });
                }
                // Driver: migrate clusters ping-pong and run rebalance
                // rounds until the searchers finish, checking invariants
                // after every round.
                let engine = &engine;
                let done = &done;
                scope.spawn(move || {
                    let index = engine.index();
                    let Some(sharded) = index.as_any().downcast_ref::<ShardedEdgeIndex>() else {
                        return; // shards=1 leg: nothing to migrate
                    };
                    let mut rng = Rng::new(seed ^ 0xD1DE);
                    let globals: Vec<u32> = sharded
                        .cluster_loads()
                        .iter()
                        .flatten()
                        .map(|c| c.global)
                        .collect();
                    loop {
                        for i in 0..4 {
                            let g = globals[rng.below(globals.len())];
                            // Guarantee real movement: the first pick per
                            // round targets a different shard.
                            let cur = sharded.shard_of(g);
                            let to = if i == 0 {
                                (cur + 1) % sharded.shards()
                            } else {
                                rng.below(sharded.shards())
                            };
                            sharded.migrate_cluster(g, to).unwrap();
                        }
                        sharded.rebalance().unwrap();
                        sharded.verify_integrity().unwrap();
                        if done.load(Ordering::Acquire) {
                            break;
                        }
                    }
                });
            });

            // Migration must actually have happened for the sharded legs.
            if shards > 1 {
                let index = engine.index();
                let stats = index.shard_stats().unwrap();
                let moved: u64 = stats.iter().map(|s| s.migrated_in).sum();
                assert!(moved > 0, "driver performed no migrations");
            }
        }
    }
}

#[test]
fn sequential_randomized_churn_matches_oracle_replay() {
    // Replay one seeded op sequence against the sharded index and a
    // single-shard oracle: searches (uncommitted, so cache capacity
    // splits cannot legitimately diverge events) must match bit for bit,
    // inserts must land in identically numbered clusters, removals may
    // drain any cluster through the merge threshold to empty (merges
    // now route globally, so they are part of the compared op space),
    // and the invariant suite must hold after every rebalance round.
    let seed = test_seed(0x5EC1);
    for shards in merge_shard_counts() {
        let b_o = builder(1, &format!("seq-oracle-{shards}"));
        let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut oracle, _mem_o) = b_o.index(&built_o, IndexKind::EdgeRag).unwrap();

        let b = builder(shards, &format!("seq-{shards}"));
        let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut subject, _mem_s) = b.index(&built, IndexKind::EdgeRag).unwrap();

        let initial_active = oracle
            .as_any()
            .downcast_ref::<EdgeIndex>()
            .unwrap()
            .active_clusters();
        let embedder = b.embedder();
        let mut rng = Rng::new(seed ^ shards as u64);
        let mut alive: Vec<u32> = (0..built.corpus.len() as u32).collect();
        let mut next_id = built.corpus.len() as u32 + 1_000;
        let mut spread_checks = 0u32;

        for step in 0..320 {
            match rng.below(100) {
                // -------- search (35%) --------
                0..=34 => {
                    let q = &built.workload.queries[rng.below(built.workload.queries.len())];
                    let emb = embedder.embed_one(&q.text).unwrap();
                    let sa = oracle.search(&emb, 5).unwrap();
                    let sb = subject.search(&emb, 5).unwrap();
                    assert_eq!(sa.hits, sb.hits, "step {step} hits");
                    assert_eq!(sa.probed, sb.probed, "step {step} probes");
                    assert_eq!(sa.events.generated, sb.events.generated, "step {step}");
                    assert_eq!(sa.events.loaded, sb.events.loaded, "step {step}");
                    assert_eq!(
                        sa.ledger.total(),
                        sb.ledger.total(),
                        "step {step} modeled latency"
                    );
                }
                // -------- insert (20%) --------
                35..=54 => {
                    let text = format!("churn document {next_id} marker zzchurn{next_id}");
                    let emb = embedder.embed_one(&text).unwrap();
                    let ca = oracle.insert_chunk(next_id, &text, &emb).unwrap();
                    let cb = if subject.supports_concurrent_updates() {
                        subject.insert_chunk_concurrent(next_id, &text, &emb).unwrap()
                    } else {
                        subject.insert_chunk(next_id, &text, &emb).unwrap()
                    };
                    assert_eq!(ca, cb, "step {step}: cluster-id allocation diverged");
                    alive.push(next_id);
                    next_id += 1;
                }
                // -------- remove (30%), unrestricted --------
                55..=84 => {
                    if alive.is_empty() {
                        continue;
                    }
                    let id = removal_victim(
                        &mut rng,
                        oracle.as_any().downcast_ref::<EdgeIndex>().unwrap(),
                        &alive,
                    );
                    let ra = oracle.remove_chunk(id).unwrap();
                    let rb = if subject.supports_concurrent_updates() {
                        subject.remove_chunk_concurrent(id).unwrap()
                    } else {
                        subject.remove_chunk(id).unwrap()
                    };
                    assert_eq!(ra, rb, "step {step} removed flags");
                    assert!(ra, "step {step}: alive chunk not removed");
                    let i = alive
                        .iter()
                        .position(|&a| a == id)
                        .expect("removed chunk was tracked alive");
                    alive.swap_remove(i);
                }
                // -------- rebalance (15%) --------
                _ => {
                    if let Some(sharded) = subject.as_any().downcast_ref::<ShardedEdgeIndex>() {
                        let r = sharded.rebalance().unwrap();
                        assert!(r.spread_after <= r.spread_before, "step {step}: {r:?}");
                        assert!(
                            r.migrated + r.skipped == r.planned,
                            "step {step}: unexecuted plan: {r:?}"
                        );
                        sharded.verify_integrity().unwrap();
                        spread_checks += 1;
                    }
                }
            }
        }
        // Deterministic drain tail: remove the smallest cluster's chunks
        // one by one until a merge tombstones it, so every seed — not
        // just removal-lucky ones — exercises the drain-through-
        // threshold-to-empty path end to end.
        let pre_drain = oracle
            .as_any()
            .downcast_ref::<EdgeIndex>()
            .unwrap()
            .active_clusters();
        while pre_drain > 1
            && oracle
                .as_any()
                .downcast_ref::<EdgeIndex>()
                .unwrap()
                .active_clusters()
                == pre_drain
        {
            let id = oracle
                .as_any()
                .downcast_ref::<EdgeIndex>()
                .unwrap()
                .clusters()
                .clusters
                .iter()
                .filter(|m| !m.is_empty())
                .min_by_key(|m| (m.len(), m.id))
                .map(|m| m.chunk_ids[0])
                .expect("alive chunks imply a non-empty cluster");
            let ra = oracle.remove_chunk(id).unwrap();
            let rb = if subject.supports_concurrent_updates() {
                subject.remove_chunk_concurrent(id).unwrap()
            } else {
                subject.remove_chunk(id).unwrap()
            };
            assert!(ra && rb, "drain-tail removal of chunk {id}");
            let i = alive.iter().position(|&a| a == id).unwrap();
            alive.swap_remove(i);
        }

        // The widened op space must actually have drained clusters into
        // merges, and both replicas must agree on the surviving set.
        let oracle_active = oracle
            .as_any()
            .downcast_ref::<EdgeIndex>()
            .unwrap()
            .active_clusters();
        assert!(
            oracle_active < initial_active,
            "churn never merged a cluster ({initial_active} -> {oracle_active})"
        );
        if shards > 1 {
            assert!(spread_checks > 0, "op mix never exercised rebalance");
            let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
            assert_eq!(
                sharded.active_clusters(),
                oracle_active,
                "active-cluster sets diverged after churn"
            );
            sharded.verify_integrity().unwrap();
            let stats = sharded.shard_stats();
            let moved: u64 = stats.iter().map(|s| s.migrated_in).sum();
            // Inserts skew the round-robin placement, so rounds must
            // eventually move something.
            assert!(moved > 0, "churn never migrated a cluster");
            let merges: u64 = stats.iter().map(|s| s.merges).sum();
            assert!(merges > 0, "churn never routed a merge");
        }

        // Terminal state agreement: every alive chunk sits in the same
        // (globally numbered) cluster on both sides.
        for &id in &alive {
            let a = oracle
                .as_any()
                .downcast_ref::<EdgeIndex>()
                .unwrap()
                .cluster_of(id);
            let b = match subject.as_any().downcast_ref::<ShardedEdgeIndex>() {
                Some(s) => s.cluster_of(id),
                None => subject.as_any().downcast_ref::<EdgeIndex>().unwrap().cluster_of(id),
            };
            assert_eq!(a, b, "chunk {id} routed differently after churn");
        }
    }
}

#[test]
fn merge_heavy_churn_matches_oracle() {
    // The CI merge leg: a removal-dominant seeded op mix replayed
    // through the full engine — and through the cross-query batch
    // scheduler with bypass disabled on the batching legs, so every
    // search takes the fused-probe path whose snapshots merges keep
    // invalidating. Clusters drain through MERGE_THRESHOLD continuously;
    // every search must stay bit-identical (hits, events, modeled
    // retrieval) to a single-shard oracle engine replaying the same ops,
    // at shards ∈ {1, 2, 4, 8}.
    let seed = test_seed(0x3E67);
    for shards in merge_shard_counts() {
        for batching in batching_modes() {
            if batching && !reference_backend() {
                continue;
            }
            let tag = format!("mh-{shards}-{batching}");
            let mut b_o = builder(1, &format!("{tag}-oracle"));
            b_o.retrieval.cache_capacity_bytes = 32 << 20;
            let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
            let oracle = b_o.pipeline(&built_o, IndexKind::EdgeRag).unwrap();
            oracle.index_mut().pin_threshold(0.0);

            let mut b = builder(shards, &tag);
            // Ample budget: the per-shard capacity slice must never bind,
            // so cache behaviour (and with it events + modeled latency)
            // cannot legitimately diverge from the unsharded policy.
            b.retrieval.cache_capacity_bytes = 32 << 20;
            let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
            let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());
            engine.index_mut().pin_threshold(0.0);
            let sched = batching.then(|| {
                BatchScheduler::new(
                    engine.clone(),
                    SchedConfig {
                        batch_window_us: 200,
                        max_inflight: 0,
                        bypass: false,
                    },
                )
            });

            let mut rng = Rng::new(seed ^ ((shards as u64) << 1) ^ batching as u64);
            let mut alive: Vec<u32> = (0..built.corpus.len() as u32).collect();
            for step in 0..260 {
                match rng.below(100) {
                    // -------- search (30%) --------
                    0..=29 => {
                        let q =
                            &built.workload.queries[rng.below(built.workload.queries.len())].text;
                        let a = oracle.handle(q).unwrap();
                        let s = match &sched {
                            Some(sched) => sched.handle(q).unwrap(),
                            None => engine.handle(q).unwrap(),
                        };
                        assert_eq!(a.hits, s.hits, "step {step} hits");
                        assert_eq!(
                            a.events.generated, s.events.generated,
                            "step {step} generated"
                        );
                        assert_eq!(a.events.loaded, s.events.loaded, "step {step} loaded");
                        assert_eq!(
                            a.events.cache_hits, s.events.cache_hits,
                            "step {step} cache hits"
                        );
                        assert_eq!(a.retrieval, s.retrieval, "step {step} modeled retrieval");
                    }
                    // -------- insert (15%) --------
                    30..=44 => {
                        let text = format!("merge heavy doc {step} marker zzmh{step}");
                        let a = oracle.insert(&text).unwrap();
                        let s = engine.insert(&text).unwrap();
                        assert_eq!(a, s, "step {step}: insert id/cluster diverged");
                        alive.push(a.0);
                    }
                    // -------- remove (45%): the merge pressure --------
                    45..=89 => {
                        if alive.is_empty() {
                            continue;
                        }
                        let id = {
                            let guard = oracle.index();
                            let edge = guard.as_any().downcast_ref::<EdgeIndex>().unwrap();
                            removal_victim(&mut rng, edge, &alive)
                        };
                        let ra = oracle.remove(id).unwrap();
                        let rs = engine.remove(id).unwrap();
                        assert_eq!(ra, rs, "step {step} removed flags");
                        assert!(ra, "step {step}: alive chunk not removed");
                        let i = alive.iter().position(|&a| a == id).unwrap();
                        alive.swap_remove(i);
                    }
                    // -------- rebalance (10%) --------
                    _ => {
                        engine.rebalance().unwrap();
                        let guard = engine.index();
                        if let Some(sh) = guard.as_any().downcast_ref::<ShardedEdgeIndex>() {
                            sh.verify_integrity().unwrap();
                        }
                    }
                }
            }
            if let Some(sched) = sched {
                sched.shutdown();
            }

            // Deterministic drain tail (mirrors the sequential suite):
            // guarantee at least one drain-through-threshold merge on
            // every seed, including the nightly's unfixed ones.
            let pre_drain = {
                let guard = oracle.index();
                guard
                    .as_any()
                    .downcast_ref::<EdgeIndex>()
                    .unwrap()
                    .active_clusters()
            };
            loop {
                let (active, id) = {
                    let guard = oracle.index();
                    let edge = guard.as_any().downcast_ref::<EdgeIndex>().unwrap();
                    let id = edge
                        .clusters()
                        .clusters
                        .iter()
                        .filter(|m| !m.is_empty())
                        .min_by_key(|m| (m.len(), m.id))
                        .map(|m| m.chunk_ids[0]);
                    (edge.active_clusters(), id)
                };
                if active != pre_drain || active <= 1 {
                    break;
                }
                let Some(id) = id else { break };
                let ra = oracle.remove(id).unwrap();
                let rs = engine.remove(id).unwrap();
                assert!(ra && rs, "drain-tail removal of chunk {id}");
                let i = alive.iter().position(|&a| a == id).unwrap();
                alive.swap_remove(i);
            }

            // Merges must actually have fired, both replicas must agree
            // on the survivors, and the caches must be in an identical
            // (globally numbered) state.
            let o_guard = oracle.index();
            let o_edge = o_guard.as_any().downcast_ref::<EdgeIndex>().unwrap();
            let s_guard = engine.index();
            assert!(
                o_edge.active_clusters()
                    < o_edge.clusters().n_clusters(),
                "merge-heavy mix never tombstoned a cluster"
            );
            assert_eq!(o_guard.cached_clusters(), s_guard.cached_clusters());
            match s_guard.as_any().downcast_ref::<ShardedEdgeIndex>() {
                Some(sh) => {
                    assert_eq!(sh.active_clusters(), o_edge.active_clusters());
                    sh.verify_integrity().unwrap();
                    let merges: u64 = sh.shard_stats().iter().map(|s| s.merges).sum();
                    assert!(merges > 0, "no merge was routed");
                }
                None => {
                    let s_edge = s_guard.as_any().downcast_ref::<EdgeIndex>().unwrap();
                    assert_eq!(s_edge.active_clusters(), o_edge.active_clusters());
                }
            }
        }
    }
}

#[test]
fn concurrent_churn_smoke_holds_invariants() {
    // All op kinds at once, with the periodic auto-rebalance trigger
    // enabled: no deadlocks, no lost chunks, invariants intact.
    let seed = test_seed(0xC0DE);
    let shards = *shard_counts().last().unwrap();
    let mut b = builder(shards, "smoke");
    b.retrieval.rebalance = true;
    b.retrieval.rebalance_interval_ops = 8;
    let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
    let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());

    let inserted: std::sync::Mutex<Vec<u32>> = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for t in 0..8usize {
            let engine = &engine;
            let built = &built;
            let inserted = &inserted;
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ (0x100 + t as u64));
                let mut mine: Vec<u32> = Vec::new();
                for step in 0..40 {
                    match rng.below(100) {
                        0..=54 => {
                            let q =
                                &built.workload.queries[rng.below(built.workload.queries.len())];
                            let out = engine.handle(&q.text).unwrap();
                            assert!(!out.hits.is_empty(), "thread {t} step {step}");
                        }
                        55..=79 => {
                            let text =
                                format!("smoke doc thread {t} step {step} zzsmoke{t}x{step}");
                            let (id, _cluster) = engine.insert(&text).unwrap();
                            mine.push(id);
                        }
                        80..=89 => {
                            if let Some(id) = mine.pop() {
                                assert!(engine.remove(id).unwrap(), "thread {t} step {step}");
                            }
                        }
                        _ => {
                            engine.rebalance().unwrap();
                        }
                    }
                }
                inserted.lock().unwrap().extend(mine);
            });
        }
    });

    let index = engine.index();
    if let Some(sharded) = index.as_any().downcast_ref::<ShardedEdgeIndex>() {
        sharded.verify_integrity().unwrap();
        for &id in inserted.lock().unwrap().iter() {
            assert!(sharded.cluster_of(id).is_some(), "chunk {id} lost");
        }
    } else {
        for &id in inserted.lock().unwrap().iter() {
            let edge = index.as_any().downcast_ref::<EdgeIndex>().unwrap();
            assert!(edge.cluster_of(id).is_some(), "chunk {id} lost");
        }
    }
}

#[test]
fn elastic_reshard_under_concurrent_traffic_matches_oracle() {
    // The PR's acceptance property: `reshard` swaps the live topology —
    // grows append empty shards, shrinks drain-then-retire — while 8
    // searcher threads hammer the engine, and every single result stays
    // bit-identical to a single-shard oracle. The churn suite's oracle
    // discipline, extended verbatim to resharding.
    let seed = test_seed(0xE1A5);
    for shards in merge_shard_counts() {
        if shards < 2 {
            continue; // shards=1 builds the plain (unsharded) index
        }
        for batching in batching_modes() {
            if batching && !reference_backend() {
                continue;
            }
            let tag = format!("reshard-{shards}-{batching}");
            let b_o = builder(1, &format!("{tag}-oracle"));
            let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
            let oracle = b_o.pipeline(&built_o, IndexKind::EdgeRag).unwrap();
            oracle.index_mut().pin_threshold(0.0);

            let b = builder(shards, &tag);
            let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
            let engine = Arc::new(b.pipeline(&built, IndexKind::EdgeRag).unwrap());
            engine.index_mut().pin_threshold(0.0);
            let sched = batching.then(|| {
                BatchScheduler::new(
                    engine.clone(),
                    SchedConfig {
                        batch_window_us: 300,
                        max_inflight: 0,
                        bypass: true,
                    },
                )
            });

            let queries: Vec<String> = built
                .workload
                .queries
                .iter()
                .take(16)
                .map(|q| q.text.clone())
                .collect();
            let expect: Vec<Vec<(u32, f32)>> = queries
                .iter()
                .map(|q| oracle.handle(q).unwrap().hits)
                .collect();

            let done = AtomicBool::new(false);
            std::thread::scope(|scope| {
                for t in 0..8usize {
                    let engine = &engine;
                    let sched = &sched;
                    let queries = &queries;
                    let expect = &expect;
                    let done = &done;
                    scope.spawn(move || {
                        let mut rng = Rng::new(seed ^ (t as u64 + 1));
                        for round in 0..40 {
                            let i = rng.below(queries.len());
                            let out = match sched {
                                Some(s) => s.handle(&queries[i]).unwrap(),
                                None => engine.handle(&queries[i]).unwrap(),
                            };
                            assert_eq!(
                                out.hits, expect[i],
                                "thread {t} round {round} query {i} diverged mid-reshard"
                            );
                        }
                        done.store(true, Ordering::Release);
                    });
                }
                // Driver: cycle the live shard count through grows and
                // shrinks until the searchers finish, checking the
                // invariant suite after every topology swap.
                let engine = &engine;
                let done = &done;
                scope.spawn(move || {
                    let index = engine.index();
                    let sharded = index.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
                    let targets = [shards * 2, 1, 3, shards];
                    let mut migrated_total = 0usize;
                    'outer: loop {
                        for &target in &targets {
                            let r = sharded.reshard(target).unwrap();
                            assert_eq!(r.to, target, "reshard landed off-target: {r:?}");
                            assert_eq!(sharded.shards(), target, "live count != report");
                            migrated_total += r.migrated;
                            // Fill freshly grown (empty) shards so the
                            // next shrink has something to drain.
                            sharded.rebalance().unwrap();
                            sharded.verify_integrity().unwrap();
                            if done.load(Ordering::Acquire) {
                                break 'outer;
                            }
                        }
                    }
                    assert!(migrated_total > 0, "resharding never drained a cluster");
                });
            });
        }
    }
}

#[test]
fn sequential_churn_with_reshard_rounds_matches_oracle() {
    // The sequential randomized suite with `reshard` in the op mix:
    // a seeded interleaving of search / insert / remove / reshard steps
    // replayed against the sharded index and a single-shard oracle.
    // Every search — between any pair of grow/shrink rounds — must match
    // bit for bit (hits, probes, events, modeled latency), cluster-id
    // allocation must stay identical, and the invariant suite must hold
    // after every topology swap.
    let seed = test_seed(0x4E5A);
    for shards in merge_shard_counts() {
        if shards < 2 {
            continue; // shards=1 builds the plain (unsharded) index
        }
        let b_o = builder(1, &format!("rs-seq-oracle-{shards}"));
        let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut oracle, _mem_o) = b_o.index(&built_o, IndexKind::EdgeRag).unwrap();

        let b = builder(shards, &format!("rs-seq-{shards}"));
        let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (mut subject, _mem_s) = b.index(&built, IndexKind::EdgeRag).unwrap();

        let embedder = b.embedder();
        let mut rng = Rng::new(seed ^ shards as u64);
        let mut alive: Vec<u32> = (0..built.corpus.len() as u32).collect();
        let mut next_id = built.corpus.len() as u32 + 5_000;
        let targets = [shards * 2, 1, 3, 8, shards];
        let mut reshards = 0usize;
        let mut migrated_total = 0usize;

        for step in 0..240 {
            match rng.below(100) {
                // -------- search (40%) --------
                0..=39 => {
                    let q = &built.workload.queries[rng.below(built.workload.queries.len())];
                    let emb = embedder.embed_one(&q.text).unwrap();
                    let sa = oracle.search(&emb, 5).unwrap();
                    let sb = subject.search(&emb, 5).unwrap();
                    assert_eq!(sa.hits, sb.hits, "step {step} hits");
                    assert_eq!(sa.probed, sb.probed, "step {step} probes");
                    assert_eq!(sa.events.generated, sb.events.generated, "step {step}");
                    assert_eq!(sa.events.loaded, sb.events.loaded, "step {step}");
                    assert_eq!(
                        sa.ledger.total(),
                        sb.ledger.total(),
                        "step {step} modeled latency"
                    );
                }
                // -------- insert (20%) --------
                40..=59 => {
                    let text = format!("reshard churn doc {next_id} marker zzrs{next_id}");
                    let emb = embedder.embed_one(&text).unwrap();
                    let ca = oracle.insert_chunk(next_id, &text, &emb).unwrap();
                    let cb = subject.insert_chunk_concurrent(next_id, &text, &emb).unwrap();
                    assert_eq!(ca, cb, "step {step}: cluster-id allocation diverged");
                    alive.push(next_id);
                    next_id += 1;
                }
                // -------- remove (28%), unrestricted --------
                60..=87 => {
                    if alive.is_empty() {
                        continue;
                    }
                    let id = removal_victim(
                        &mut rng,
                        oracle.as_any().downcast_ref::<EdgeIndex>().unwrap(),
                        &alive,
                    );
                    let ra = oracle.remove_chunk(id).unwrap();
                    let rb = subject.remove_chunk_concurrent(id).unwrap();
                    assert_eq!(ra, rb, "step {step} removed flags");
                    let i = alive.iter().position(|&a| a == id).unwrap();
                    alive.swap_remove(i);
                }
                // -------- reshard (12%) --------
                _ => {
                    let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
                    let target = targets[reshards % targets.len()];
                    let r = sharded.reshard(target).unwrap();
                    assert_eq!(sharded.shards(), target, "step {step}: {r:?}");
                    migrated_total += r.migrated;
                    reshards += 1;
                    // A rebalance round right after fills freshly grown
                    // shards (grow alone appends empty ones).
                    sharded.rebalance().unwrap();
                    sharded.verify_integrity().unwrap();
                }
            }
        }
        assert!(reshards >= 2, "op mix never resharded");
        assert!(migrated_total > 0, "shrink rounds never drained a cluster");

        // Terminal state agreement after the grow/shrink churn.
        let oracle_edge = oracle.as_any().downcast_ref::<EdgeIndex>().unwrap();
        let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();
        sharded.verify_integrity().unwrap();
        assert_eq!(
            sharded.active_clusters(),
            oracle_edge.active_clusters(),
            "active-cluster sets diverged after reshard churn"
        );
        for &id in &alive {
            assert_eq!(
                oracle_edge.cluster_of(id),
                sharded.cluster_of(id),
                "chunk {id} routed differently after reshard churn"
            );
        }
    }
}

#[test]
fn skewed_placement_rebalances_under_live_traffic() {
    // The bench-sweep property as a test: seed one shard with every
    // cluster (the worst drift), then require bounded rebalance rounds
    // to cut the load spread in half while searches stay bit-identical
    // to an untouched oracle.
    let _ = test_seed(0x5CE3); // print the seed header for CI logs
    for shards in shard_counts() {
        if shards < 2 {
            continue;
        }
        let b_o = builder(1, &format!("skew-oracle-{shards}"));
        let built_o = b_o.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (oracle, _mem_o) = b_o.index(&built_o, IndexKind::EdgeRag).unwrap();

        let b = builder(shards, &format!("skew-{shards}"));
        let built = b.build_dataset(&DatasetProfile::tiny()).unwrap();
        let (subject, _mem_s) = b.index(&built, IndexKind::EdgeRag).unwrap();
        let sharded = subject.as_any().downcast_ref::<ShardedEdgeIndex>().unwrap();

        let loads = sharded.cluster_loads();
        let globals: Vec<u32> = loads.iter().flatten().map(|c| c.global).collect();
        let max_load = loads.iter().flatten().map(|c| c.load()).max().unwrap();
        for &g in &globals {
            sharded.migrate_cluster(g, 0).unwrap();
        }
        sharded.verify_integrity().unwrap();
        let before = sharded.load_spread();
        assert!(before > 0, "all-on-one-shard placement must show spread");

        let embedder = b.embedder();
        let mut rounds = 0;
        loop {
            let r = sharded.rebalance().unwrap();
            sharded.verify_integrity().unwrap();
            rounds += 1;
            // Live traffic between rounds stays oracle-identical.
            let q = &built.workload.queries[rounds % built.workload.queries.len()];
            let emb = embedder.embed_one(&q.text).unwrap();
            assert_eq!(
                oracle.search(&emb, 5).unwrap().hits,
                subject.search(&emb, 5).unwrap().hits,
                "round {rounds}"
            );
            if r.migrated == 0 || rounds >= 16 {
                break;
            }
        }
        // The greedy equalizer's guaranteed endpoint: spread halves, or
        // is pinned by indivisibly large clusters (a stuck donor's every
        // cluster exceeds half the remaining gap).
        let after = sharded.load_spread();
        assert!(
            after < before && after <= (before / 2).max(2 * max_load),
            "spread {before} -> {after} (max cluster load {max_load}) after {rounds} rounds"
        );
    }
}
