//! EdgeRAG: online-indexed retrieval-augmented generation for edge devices.
//!
//! Reproduction of "EdgeRAG: Online-Indexed RAG for Edge Devices"
//! (Seemakhupt, Liu, Khan — 2024). Three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the rust serving coordinator: two-level IVF
//!   index with pruned second-level embeddings, online embedding generation,
//!   selective tail-cluster storage, cost-aware adaptive caching, SLO-aware
//!   retrieval pipeline and request server.
//! * **Layer 2 (`python/compile/model.py`)** — JAX compute graphs (embedding
//!   model forward pass, similarity scorers, LLM prefill proxy), AOT-lowered
//!   to HLO text at build time.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   similarity/search and projection hot spots, lowered into the same HLO.
//!
//! Python never runs on the request path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) once at
//! startup and serves from compiled executables.

pub mod cache;
pub mod config;
pub mod data;
pub mod embedding;
pub mod coordinator;
pub mod eval;
pub mod index;
pub mod json;
pub mod llm;
pub mod pool;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod simtime;
pub mod storage;
pub mod testutil;
pub mod trace;
pub mod vecmath;
