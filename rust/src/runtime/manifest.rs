//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. `artifacts/manifest.json` lists every AOT-lowered HLO
//! module, its input specs (weight blobs vs. runtime inputs) and output
//! shapes.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype `{other}`"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    /// Loaded once from `file` at startup, uploaded to the device and
    /// reused across calls.
    Weight,
    /// Provided by the caller on every execution.
    Input,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub kind: InputKind,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub file: Option<String>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let kind = match v.req("kind")?.as_str() {
            Some("weight") => InputKind::Weight,
            Some("input") => InputKind::Input,
            other => bail!("bad input kind {other:?}"),
        };
        Ok(TensorSpec {
            kind,
            dtype: DType::parse(v.req("dtype")?.as_str().context("dtype not a string")?)?,
            shape: parse_shape(v.req("shape")?)?,
            file: v.get("file").and_then(|f| f.as_str()).map(String::from),
        })
    }
}

#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<OutputSpec>,
}

impl ArtifactSpec {
    pub fn runtime_inputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.inputs.iter().filter(|i| i.kind == InputKind::Input)
    }
}

fn parse_shape(v: &Value) -> Result<Vec<usize>> {
    v.as_array()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().context("shape dim not an integer"))
        .collect()
}

fn parse_usize_list(v: &Value) -> Result<Vec<usize>> {
    parse_shape(v)
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dim: usize,
    pub vocab: usize,
    pub enc_seq: usize,
    pub prefill_seq: usize,
    pub sim_rows: Vec<usize>,
    /// Query-batch widths of the similarity family: `sim_{A}x{N}` is
    /// lowered for every A in this list × N in `sim_rows`. `[1]` for
    /// manifests predating cross-query batching (single-query kernels
    /// plus the fixed `sim_32x512` k-means artifact).
    pub sim_batches: Vec<usize>,
    pub proj_batches: Vec<usize>,
    pub enc_batches: Vec<usize>,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// The shapes `python/compile/aot.py` lowers (model.py constants +
    /// shape buckets), used when no `artifacts/manifest.json` exists so
    /// the reference compute backend can serve without a build step. Must
    /// stay in sync with `aot.py` (`SIM_ROWS`, `PROJ_BATCHES`,
    /// `ENC_BATCHES`) and `model.py` (`DIM`, `VOCAB`, `ENC_SEQ`,
    /// `PREFILL_SEQ`).
    pub fn builtin(dir: &Path) -> Manifest {
        Manifest {
            dim: 256,
            vocab: 4096,
            enc_seq: 64,
            prefill_seq: 256,
            sim_rows: vec![128, 256, 512, 1024, 4096],
            sim_batches: vec![1, 8, 32],
            proj_batches: vec![1, 32],
            enc_batches: vec![1, 8],
            artifacts: Vec::new(),
            dir: dir.to_path_buf(),
        }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = Vec::new();
        for a in v.req("artifacts")?.as_array().context("artifacts not an array")? {
            let inputs = a
                .req("inputs")?
                .as_array()
                .context("inputs not an array")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_array()
                .context("outputs not an array")?
                .iter()
                .map(|o| {
                    Ok(OutputSpec {
                        dtype: o
                            .req("dtype")?
                            .as_str()
                            .context("output dtype")?
                            .to_string(),
                        shape: parse_shape(o.req("shape")?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: a.req("name")?.as_str().context("name")?.to_string(),
                hlo: a.req("hlo")?.as_str().context("hlo")?.to_string(),
                inputs,
                outputs,
            });
        }

        Ok(Manifest {
            dim: v.req("dim")?.as_usize().context("dim")?,
            vocab: v.req("vocab")?.as_usize().context("vocab")?,
            enc_seq: v.req("enc_seq")?.as_usize().context("enc_seq")?,
            prefill_seq: v.req("prefill_seq")?.as_usize().context("prefill_seq")?,
            sim_rows: parse_usize_list(v.req("sim_rows")?)?,
            // Optional for manifests built before cross-query batching:
            // they only lowered single-query sim kernels.
            sim_batches: match v.get("sim_batches") {
                Some(b) => parse_usize_list(b)?,
                None => vec![1],
            },
            proj_batches: parse_usize_list(v.req("proj_batches")?)?,
            enc_batches: parse_usize_list(v.req("enc_batches")?)?,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        match self.artifacts.iter().find(|a| a.name == name) {
            Some(a) => Ok(a),
            None => bail!("artifact `{name}` not in manifest"),
        }
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.hlo)
    }

    /// Read a weight blob (flat little-endian f32) for a weight input.
    pub fn read_weights(&self, spec: &TensorSpec) -> Result<Vec<f32>> {
        let file = spec.file.as_ref().context("weight input without a file")?;
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        if bytes.len() != spec.elements() * 4 {
            bail!(
                "{}: expected {} f32 ({} bytes), found {} bytes",
                path.display(),
                spec.elements(),
                spec.elements() * 4,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Smallest similarity bucket with at least `rows` rows, if any.
    pub fn sim_bucket(&self, rows: usize) -> Option<usize> {
        self.sim_rows.iter().copied().find(|&r| r >= rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Real-artifact tests only run after `make artifacts` (python + jax
    /// lowering). Tracking note: ROADMAP "tier-1 triage" — without the
    /// artifacts these are skipped, not failed, because the reference
    /// backend serves everything except compiled-graph parity.
    fn real_manifest() -> Option<Manifest> {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/manifest.json not built (run `make artifacts`)");
            return None;
        }
        Some(Manifest::load(&dir).expect("manifest.json exists but fails to parse"))
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = real_manifest() else { return };
        assert_eq!(m.dim, 256);
        assert_eq!(m.vocab, 4096);
        assert!(m.artifacts.len() >= 10);
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{} missing", a.hlo);
            assert!(!a.outputs.is_empty());
        }
    }

    #[test]
    fn weight_blobs_match_specs() {
        let Some(m) = real_manifest() else { return };
        for a in &m.artifacts {
            for i in a.inputs.iter().filter(|i| i.kind == InputKind::Weight) {
                let w = m.read_weights(i).unwrap();
                assert_eq!(w.len(), i.elements());
                assert!(w.iter().all(|x| x.is_finite()));
            }
        }
    }

    #[test]
    fn sim_bucket_selection() {
        // Shape buckets are contract, not build output: the built-in
        // manifest must answer identically to a real one.
        let m = Manifest::builtin(&manifest_dir());
        assert_eq!(m.sim_bucket(1), Some(128));
        assert_eq!(m.sim_bucket(128), Some(128));
        assert_eq!(m.sim_bucket(129), Some(256));
        assert_eq!(m.sim_bucket(4096), Some(4096));
        assert_eq!(m.sim_bucket(5000), None);
    }

    #[test]
    fn builtin_matches_model_constants() {
        let m = Manifest::builtin(&manifest_dir());
        assert_eq!((m.dim, m.vocab), (256, 4096));
        assert_eq!((m.enc_seq, m.prefill_seq), (64, 256));
        assert_eq!(m.sim_batches, vec![1, 8, 32]);
        assert_eq!(m.proj_batches, vec![1, 32]);
        assert_eq!(m.enc_batches, vec![1, 8]);
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::builtin(&manifest_dir());
        assert!(m.get("nope").is_err());
        let Some(m) = real_manifest() else { return };
        assert!(m.get("sim_1x128").is_ok());
    }

    #[test]
    fn enc_artifacts_have_weight_plus_two_inputs() {
        let Some(m) = real_manifest() else { return };
        let enc = m.get("enc_8").unwrap();
        assert_eq!(enc.inputs.len(), 3);
        assert_eq!(enc.inputs[0].kind, InputKind::Weight);
        assert_eq!(enc.runtime_inputs().count(), 2);
        assert_eq!(enc.outputs[0].shape, vec![8, 256]);
    }
}
