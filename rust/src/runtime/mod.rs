//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and serves compiled executables to the
//! coordinator. Python is never on this path — artifacts are plain HLO
//! text compiled through the PJRT C API at startup.
//!
//! Executables are compiled lazily on first use and memoized: tests and
//! tools that touch one model don't pay for compiling all eleven.

mod executable;
pub mod manifest;
pub mod reference;
pub mod service;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

pub use executable::{Executable, HostTensor};
pub use manifest::{ArtifactSpec, DType, InputKind, Manifest};
pub use service::{default_compute_threads, ComputeHandle, Tensor};

/// The process-wide PJRT runtime: one CPU client + compiled-executable
/// registry keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> Result<Arc<Runtime>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Runtime {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dim(&self) -> usize {
        self.manifest.dim
    }

    /// Fetch (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        // Compile outside the lock: compilation can take hundreds of ms and
        // other artifacts shouldn't block on it.
        let spec = self.manifest.get(name)?.clone();
        let exe = Arc::new(Executable::load(&self.client, &self.manifest, &spec)?);
        let mut map = self.compiled.lock().unwrap();
        let entry = map.entry(name.to_string()).or_insert_with(|| exe.clone());
        Ok(entry.clone())
    }

    /// Eagerly compile every artifact (server startup path).
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for name in names {
            self.executable(&name)?;
        }
        Ok(())
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}
