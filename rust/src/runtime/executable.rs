//! A compiled PJRT executable plus its pre-uploaded weights.
//!
//! Weights are uploaded to the device once at load time and passed by
//! buffer on every call (`execute_b`), so the request path never re-copies
//! model parameters — only the (small) activations cross the host/device
//! boundary per call.

use anyhow::{bail, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactSpec, DType, InputKind, Manifest, TensorSpec};

/// A host-side tensor argument for one execution.
#[derive(Debug, Clone, Copy)]
pub enum HostTensor<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> HostTensor<'a> {
    fn shape(&self) -> &'a [usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    fn upload(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(match self {
            HostTensor::F32(data, dims) => {
                client.buffer_from_host_buffer(data, dims, None)?
            }
            HostTensor::I32(data, dims) => {
                client.buffer_from_host_buffer(data, dims, None)?
            }
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
    /// Pre-uploaded weight buffers, positionally aligned with the weight
    /// entries of `spec.inputs`.
    weights: Vec<PjRtBuffer>,
}

impl Executable {
    /// Compile `spec` on `client`, loading + uploading its weight blobs.
    pub fn load(client: &PjRtClient, manifest: &Manifest, spec: &ArtifactSpec) -> Result<Self> {
        let path = manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;

        let mut weights = Vec::new();
        for input in spec.inputs.iter().filter(|i| i.kind == InputKind::Weight) {
            let host = manifest.read_weights(input)?;
            weights.push(client.buffer_from_host_buffer(&host, &input.shape, None)?);
        }
        Ok(Executable {
            spec: spec.clone(),
            exe,
            weights,
        })
    }

    fn check_input(spec: &TensorSpec, arg: &HostTensor, name: &str, pos: usize) -> Result<()> {
        if arg.dtype() != spec.dtype {
            bail!("{name} input {pos}: dtype mismatch");
        }
        if arg.shape() != spec.shape.as_slice() || arg.len() != spec.elements() {
            bail!(
                "{name} input {pos}: shape {:?} != spec {:?}",
                arg.shape(),
                spec.shape
            );
        }
        Ok(())
    }

    /// Execute with runtime inputs (weights are implicit). Returns the
    /// flattened f32 contents of each output, in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let client = self.exe.client();
        let runtime_specs: Vec<&TensorSpec> = self.spec.runtime_inputs().collect();
        if inputs.len() != runtime_specs.len() {
            bail!(
                "{}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                runtime_specs.len()
            );
        }

        // Assemble the full positional argument list: weights (already on
        // device) and activations (uploaded now), in spec order.
        let mut uploaded = Vec::with_capacity(inputs.len());
        for (spec, arg) in runtime_specs.iter().zip(inputs) {
            Self::check_input(spec, arg, &self.spec.name, uploaded.len())?;
            uploaded.push(arg.upload(client)?);
        }
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.spec.inputs.len());
        let (mut wi, mut ai) = (0, 0);
        for input in &self.spec.inputs {
            match input.kind {
                InputKind::Weight => {
                    args.push(&self.weights[wi]);
                    wi += 1;
                }
                InputKind::Input => {
                    args.push(&uploaded[ai]);
                    ai += 1;
                }
            }
        }

        let result = self.exe.execute_b(&args)?;
        // aot.py lowers with return_tuple=True → a single tuple output.
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        if tuple.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs, {} expected",
                self.spec.name,
                tuple.len(),
                self.spec.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            outs.push(lit.to_vec::<f32>()?);
        }
        Ok(outs)
    }
}
