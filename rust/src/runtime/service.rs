//! Compute-executor thread: the serving-engine pattern.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (single-threaded), so all
//! PJRT state — client, compiled executables, uploaded weights — lives on
//! one dedicated executor thread. Coordinator/server threads hold a cheap
//! [`ComputeHandle`] (`Clone + Send + Sync`) and submit jobs over a
//! channel; replies come back on per-call channels. This mirrors how
//! production servers isolate an inference engine behind a submission
//! queue.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Context, Result};

use super::{HostTensor, Manifest, Runtime};

/// An owned tensor argument crossing the thread boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    fn as_host(&self) -> HostTensor<'_> {
        match self {
            Tensor::F32(d, s) => HostTensor::F32(d, s),
            Tensor::I32(d, s) => HostTensor::I32(d, s),
        }
    }
}

enum Job {
    Run {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

struct Shared {
    tx: mpsc::Sender<Job>,
    manifest: Manifest,
    calls: AtomicU64,
    join: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Handle to the compute executor. Cloneable and thread-safe; dropping the
/// last handle shuts the executor down.
#[derive(Clone)]
pub struct ComputeHandle {
    shared: Arc<Shared>,
}

impl ComputeHandle {
    /// Spawn the executor thread and load the artifact manifest.
    pub fn start(artifacts_dir: &Path) -> Result<ComputeHandle> {
        // Parse the manifest on the caller thread too (it's cheap) so the
        // handle can answer shape/bucket questions without a round-trip.
        let manifest = Manifest::load(artifacts_dir)?;
        let dir: PathBuf = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("edgerag-compute".into())
            .spawn(move || executor_loop(&dir, rx, ready_tx))
            .context("spawning compute thread")?;

        ready_rx
            .recv()
            .context("compute thread died during startup")??;

        Ok(ComputeHandle {
            shared: Arc::new(Shared {
                tx,
                manifest,
                calls: AtomicU64::new(0),
                join: std::sync::Mutex::new(Some(join)),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    pub fn dim(&self) -> usize {
        self.shared.manifest.dim
    }

    /// Total executions submitted through this service.
    pub fn calls(&self) -> u64 {
        self.shared.calls.load(Ordering::Relaxed)
    }

    /// Execute an artifact with owned inputs; blocks for the result.
    pub fn run(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Vec<f32>>> {
        self.shared.calls.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.shared
            .tx
            .send(Job::Run {
                artifact: artifact.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("compute thread gone"))?;
        rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
    }

    /// Eagerly compile all artifacts (server startup).
    pub fn warmup(&self) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.shared
            .tx
            .send(Job::Warmup { reply })
            .map_err(|_| anyhow!("compute thread gone"))?;
        rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

fn executor_loop(dir: &Path, rx: mpsc::Receiver<Job>, ready: mpsc::Sender<Result<()>>) {
    let runtime = match Runtime::load(dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        match job {
            Job::Run {
                artifact,
                inputs,
                reply,
            } => {
                let res = runtime.executable(&artifact).and_then(|exe| {
                    let host: Vec<HostTensor> = inputs.iter().map(|t| t.as_host()).collect();
                    exe.run(&host)
                });
                let _ = reply.send(res);
            }
            Job::Warmup { reply } => {
                let _ = reply.send(runtime.warmup());
            }
            Job::Shutdown => break,
        }
    }
}
