//! Compute service: the serving-engine pattern, with two backends.
//!
//! * **PJRT** — the `xla` crate's handles are `Rc`-based
//!   (single-threaded), so PJRT state — client, compiled executables,
//!   uploaded weights — is thread-confined. Instead of one executor
//!   thread, the service runs a **pool of N executor threads**, each
//!   owning its own [`Runtime`] (its own client + executable cache),
//!   all draining one shared job queue. Coordinator/server threads hold
//!   a cheap [`ComputeHandle`] (`Clone + Send + Sync`) and submit jobs
//!   over the queue; replies come back on per-call channels. N defaults
//!   to the core count (clamped to 16) and is settable with the
//!   `--compute-threads` CLI knob, so the compiled backend scales with
//!   cores the way the reference backend always has.
//! * **Reference** — when PJRT (or the `artifacts/` directory) is
//!   unavailable, the service transparently falls back to the
//!   deterministic pure-rust [`RefCompute`](super::reference::RefCompute)
//!   backend, which is `Sync` and executes **inline on the calling
//!   thread** — so concurrent queries scale with cores instead of
//!   funneling through the executor queue.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::reference::RefCompute;
use super::{HostTensor, Manifest, Runtime};

/// An owned tensor argument crossing the thread boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    fn as_host(&self) -> HostTensor<'_> {
        match self {
            Tensor::F32(d, s) => HostTensor::F32(d, s),
            Tensor::I32(d, s) => HostTensor::I32(d, s),
        }
    }
}

enum Job {
    Run {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

enum Backend {
    /// Executor pool driving compiled PJRT executables: one `Runtime`
    /// per thread, one shared MPSC queue. The sender sits behind a mutex
    /// so the handle stays `Sync` on every toolchain; the lock is held
    /// only for the (non-blocking) enqueue. `threads` is the number of
    /// workers that survived startup — shutdown sends that many
    /// `Shutdown` jobs, each consumed by exactly one worker.
    Pjrt {
        tx: Mutex<mpsc::Sender<Job>>,
        joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
        threads: usize,
    },
    /// In-process deterministic fallback; executes on the caller thread.
    Reference(RefCompute),
}

struct Shared {
    backend: Backend,
    manifest: Manifest,
    calls: AtomicU64,
}

/// Default executor-pool width: one worker per core, clamped to 1..=16
/// (matches the shard-count clamp — past that, queue contention beats
/// parallel compile wins on edge parts).
pub fn default_compute_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 16)
}

/// Handle to the compute service. Cloneable and thread-safe; dropping the
/// last handle shuts a PJRT executor pool down.
#[derive(Clone)]
pub struct ComputeHandle {
    shared: Arc<Shared>,
}

impl ComputeHandle {
    /// Start the compute service for `artifacts_dir` with the default
    /// (per-core) executor-pool width.
    pub fn start(artifacts_dir: &Path) -> Result<ComputeHandle> {
        Self::start_with_threads(artifacts_dir, 0)
    }

    /// Start the compute service with an explicit executor-pool width
    /// (`0` means auto: [`default_compute_threads`]).
    ///
    /// Tries, in order: real manifest + PJRT executor pool; real
    /// manifest + reference backend (PJRT unavailable); built-in manifest
    /// + reference backend (no artifacts at all). The caller never has to
    /// care which one it got — only golden-parity tests do.
    pub fn start_with_threads(artifacts_dir: &Path, threads: usize) -> Result<ComputeHandle> {
        let threads = if threads == 0 {
            default_compute_threads()
        } else {
            threads
        };
        let manifest = match Manifest::load(artifacts_dir) {
            Ok(m) => m,
            Err(e) => {
                eprintln!(
                    "edgerag: no compiled artifacts ({e:#}); \
                     using the built-in manifest + reference compute backend"
                );
                Manifest::builtin(artifacts_dir)
            }
        };
        let backend = match spawn_pjrt_pool(artifacts_dir, threads) {
            Ok((tx, joins)) => {
                let threads = joins.len();
                Backend::Pjrt {
                    tx: Mutex::new(tx),
                    joins: Mutex::new(joins),
                    threads,
                }
            }
            Err(e) => {
                eprintln!(
                    "edgerag: PJRT executor unavailable ({e:#}); \
                     falling back to the pure-rust reference compute backend"
                );
                Backend::Reference(RefCompute::new(&manifest))
            }
        };
        Ok(ComputeHandle {
            shared: Arc::new(Shared {
                backend,
                manifest,
                calls: AtomicU64::new(0),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    pub fn dim(&self) -> usize {
        self.shared.manifest.dim
    }

    /// Which backend is serving compute — "pjrt" or "reference".
    pub fn backend_name(&self) -> &'static str {
        match self.shared.backend {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Reference(_) => "reference",
        }
    }

    /// Width of the PJRT executor pool, or `0` for the reference backend
    /// (which runs inline on callers — effectively one lane per caller).
    pub fn executor_threads(&self) -> usize {
        match &self.shared.backend {
            Backend::Pjrt { threads, .. } => *threads,
            Backend::Reference(_) => 0,
        }
    }

    /// Total executions submitted through this service.
    pub fn calls(&self) -> u64 {
        self.shared.calls.load(Ordering::Relaxed)
    }

    /// Execute an artifact with owned inputs; blocks for the result. On
    /// the reference backend this runs inline on the calling thread, so
    /// concurrent callers execute concurrently; on PJRT the job is
    /// picked up by whichever pool worker frees first.
    pub fn run(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Vec<f32>>> {
        self.shared.calls.fetch_add(1, Ordering::Relaxed);
        match &self.shared.backend {
            Backend::Pjrt { tx, .. } => {
                let (reply, rx) = mpsc::channel();
                tx.lock()
                    .unwrap()
                    .send(Job::Run {
                        artifact: artifact.to_string(),
                        inputs,
                        reply,
                    })
                    .map_err(|_| anyhow!("compute pool gone"))?;
                rx.recv().map_err(|_| anyhow!("compute pool dropped reply"))?
            }
            Backend::Reference(r) => r.run(artifact, &inputs),
        }
    }

    /// Eagerly compile all artifacts (server startup). One warmup job
    /// per pool worker, so every per-thread executable cache is primed;
    /// a worker that misses its job (another drained two) still compiles
    /// lazily on first use. No-op on the reference backend.
    pub fn warmup(&self) -> Result<()> {
        match &self.shared.backend {
            Backend::Pjrt { tx, threads, .. } => {
                let (reply, rx) = mpsc::channel();
                {
                    let tx = tx.lock().unwrap();
                    for _ in 0..*threads {
                        tx.send(Job::Warmup {
                            reply: reply.clone(),
                        })
                        .map_err(|_| anyhow!("compute pool gone"))?;
                    }
                }
                drop(reply);
                let mut result = Ok(());
                while let Ok(r) = rx.recv() {
                    if r.is_err() && result.is_ok() {
                        result = r;
                    }
                }
                result
            }
            Backend::Reference(_) => Ok(()),
        }
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        if let Backend::Pjrt { tx, joins, threads } = &self.backend {
            {
                let tx = tx.lock().unwrap();
                // One Shutdown per live worker; each worker exits after
                // consuming exactly one.
                for _ in 0..*threads {
                    let _ = tx.send(Job::Shutdown);
                }
            }
            for j in joins.lock().unwrap().drain(..) {
                let _ = j.join();
            }
        }
    }
}

/// Spawn the PJRT executor pool; fails fast (with the underlying PJRT /
/// artifact error) when **no** worker can load the runtime, so `start`
/// can fall back. Workers that fail individually (e.g. device memory
/// exhausted after the first few clients) are dropped from the pool;
/// any surviving subset keeps the service alive.
fn spawn_pjrt_pool(
    dir: &Path,
    threads: usize,
) -> Result<(mpsc::Sender<Job>, Vec<std::thread::JoinHandle<()>>)> {
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = Arc::new(Mutex::new(rx));
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

    let mut handles = Vec::with_capacity(threads);
    for i in 0..threads {
        let dir: PathBuf = dir.to_path_buf();
        let rx = Arc::clone(&rx);
        let ready = ready_tx.clone();
        let join = std::thread::Builder::new()
            .name(format!("edgerag-compute-{i}"))
            .spawn(move || executor_loop(&dir, rx, ready))
            .context("spawning compute pool thread")?;
        handles.push(join);
    }
    drop(ready_tx);

    let mut ok = 0usize;
    let mut first_err: Option<anyhow::Error> = None;
    for _ in 0..threads {
        match ready_rx.recv() {
            Ok(Ok(())) => ok += 1,
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(anyhow!("compute pool thread died during startup"));
                }
            }
        }
    }
    if ok == 0 {
        for j in handles {
            let _ = j.join();
        }
        return Err(first_err.unwrap_or_else(|| anyhow!("empty compute pool")));
    }
    // Keep only the workers that reported ready; the failed ones have
    // already exited — reap their join handles now.
    if ok < threads {
        let (live, dead): (Vec<_>, Vec<_>) =
            handles.into_iter().partition(|j| !j.is_finished());
        for j in dead {
            let _ = j.join();
        }
        handles = live;
    }
    Ok((tx, handles))
}

fn executor_loop(
    dir: &Path,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    ready: mpsc::Sender<Result<()>>,
) {
    let runtime = match Runtime::load(dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        // Hold the queue lock only for the dequeue, never across an
        // execution, so the other pool workers keep draining.
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => break,
        };
        match job {
            Job::Run {
                artifact,
                inputs,
                reply,
            } => {
                let res = runtime.executable(&artifact).and_then(|exe| {
                    let host: Vec<HostTensor> = inputs.iter().map(|t| t.as_host()).collect();
                    exe.run(&host)
                });
                let _ = reply.send(res);
            }
            Job::Warmup { reply } => {
                let _ = reply.send(runtime.warmup());
            }
            Job::Shutdown => break,
        }
    }
}
