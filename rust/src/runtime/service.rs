//! Compute service: the serving-engine pattern, with two backends.
//!
//! * **PJRT** — the `xla` crate's handles are `Rc`-based
//!   (single-threaded), so all PJRT state — client, compiled executables,
//!   uploaded weights — lives on one dedicated executor thread.
//!   Coordinator/server threads hold a cheap [`ComputeHandle`]
//!   (`Clone + Send + Sync`) and submit jobs over a channel; replies come
//!   back on per-call channels. This mirrors how production servers
//!   isolate an inference engine behind a submission queue.
//! * **Reference** — when PJRT (or the `artifacts/` directory) is
//!   unavailable, the service transparently falls back to the
//!   deterministic pure-rust [`RefCompute`](super::reference::RefCompute)
//!   backend, which is `Sync` and executes **inline on the calling
//!   thread** — so concurrent queries scale with cores instead of
//!   funneling through the executor channel.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::reference::RefCompute;
use super::{HostTensor, Manifest, Runtime};

/// An owned tensor argument crossing the thread boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    fn as_host(&self) -> HostTensor<'_> {
        match self {
            Tensor::F32(d, s) => HostTensor::F32(d, s),
            Tensor::I32(d, s) => HostTensor::I32(d, s),
        }
    }
}

enum Job {
    Run {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

enum Backend {
    /// Dedicated executor thread driving compiled PJRT executables. The
    /// sender sits behind a mutex so the handle stays `Sync` on every
    /// toolchain; the lock is held only for the (non-blocking) enqueue.
    Pjrt {
        tx: Mutex<mpsc::Sender<Job>>,
        join: Mutex<Option<std::thread::JoinHandle<()>>>,
    },
    /// In-process deterministic fallback; executes on the caller thread.
    Reference(RefCompute),
}

struct Shared {
    backend: Backend,
    manifest: Manifest,
    calls: AtomicU64,
}

/// Handle to the compute service. Cloneable and thread-safe; dropping the
/// last handle shuts a PJRT executor down.
#[derive(Clone)]
pub struct ComputeHandle {
    shared: Arc<Shared>,
}

impl ComputeHandle {
    /// Start the compute service for `artifacts_dir`.
    ///
    /// Tries, in order: real manifest + PJRT executor thread; real
    /// manifest + reference backend (PJRT unavailable); built-in manifest
    /// + reference backend (no artifacts at all). The caller never has to
    /// care which one it got — only golden-parity tests do.
    pub fn start(artifacts_dir: &Path) -> Result<ComputeHandle> {
        let manifest = match Manifest::load(artifacts_dir) {
            Ok(m) => m,
            Err(e) => {
                eprintln!(
                    "edgerag: no compiled artifacts ({e:#}); \
                     using the built-in manifest + reference compute backend"
                );
                Manifest::builtin(artifacts_dir)
            }
        };
        let backend = match spawn_pjrt_executor(artifacts_dir) {
            Ok((tx, join)) => Backend::Pjrt {
                tx: Mutex::new(tx),
                join: Mutex::new(Some(join)),
            },
            Err(e) => {
                eprintln!(
                    "edgerag: PJRT executor unavailable ({e:#}); \
                     falling back to the pure-rust reference compute backend"
                );
                Backend::Reference(RefCompute::new(&manifest))
            }
        };
        Ok(ComputeHandle {
            shared: Arc::new(Shared {
                backend,
                manifest,
                calls: AtomicU64::new(0),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    pub fn dim(&self) -> usize {
        self.shared.manifest.dim
    }

    /// Which backend is serving compute — "pjrt" or "reference".
    pub fn backend_name(&self) -> &'static str {
        match self.shared.backend {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Reference(_) => "reference",
        }
    }

    /// Total executions submitted through this service.
    pub fn calls(&self) -> u64 {
        self.shared.calls.load(Ordering::Relaxed)
    }

    /// Execute an artifact with owned inputs; blocks for the result. On
    /// the reference backend this runs inline on the calling thread, so
    /// concurrent callers execute concurrently.
    pub fn run(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Vec<f32>>> {
        self.shared.calls.fetch_add(1, Ordering::Relaxed);
        match &self.shared.backend {
            Backend::Pjrt { tx, .. } => {
                let (reply, rx) = mpsc::channel();
                tx.lock()
                    .unwrap()
                    .send(Job::Run {
                        artifact: artifact.to_string(),
                        inputs,
                        reply,
                    })
                    .map_err(|_| anyhow!("compute thread gone"))?;
                rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
            }
            Backend::Reference(r) => r.run(artifact, &inputs),
        }
    }

    /// Eagerly compile all artifacts (server startup). No-op on the
    /// reference backend.
    pub fn warmup(&self) -> Result<()> {
        match &self.shared.backend {
            Backend::Pjrt { tx, .. } => {
                let (reply, rx) = mpsc::channel();
                tx.lock()
                    .unwrap()
                    .send(Job::Warmup { reply })
                    .map_err(|_| anyhow!("compute thread gone"))?;
                rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
            }
            Backend::Reference(_) => Ok(()),
        }
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        if let Backend::Pjrt { tx, join } = &self.backend {
            let _ = tx.lock().unwrap().send(Job::Shutdown);
            if let Some(j) = join.lock().unwrap().take() {
                let _ = j.join();
            }
        }
    }
}

/// Spawn the PJRT executor thread; fails fast (with the underlying PJRT /
/// artifact error) when the runtime cannot load, so `start` can fall back.
fn spawn_pjrt_executor(
    dir: &Path,
) -> Result<(mpsc::Sender<Job>, std::thread::JoinHandle<()>)> {
    let dir: PathBuf = dir.to_path_buf();
    let (tx, rx) = mpsc::channel::<Job>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

    let join = std::thread::Builder::new()
        .name("edgerag-compute".into())
        .spawn(move || executor_loop(&dir, rx, ready_tx))
        .context("spawning compute thread")?;

    match ready_rx.recv() {
        Ok(Ok(())) => Ok((tx, join)),
        Ok(Err(e)) => {
            let _ = join.join();
            Err(e)
        }
        Err(_) => {
            let _ = join.join();
            Err(anyhow!("compute thread died during startup"))
        }
    }
}

fn executor_loop(dir: &Path, rx: mpsc::Receiver<Job>, ready: mpsc::Sender<Result<()>>) {
    let runtime = match Runtime::load(dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        match job {
            Job::Run {
                artifact,
                inputs,
                reply,
            } => {
                let res = runtime.executable(&artifact).and_then(|exe| {
                    let host: Vec<HostTensor> = inputs.iter().map(|t| t.as_host()).collect();
                    exe.run(&host)
                });
                let _ = reply.send(res);
            }
            Job::Warmup { reply } => {
                let _ = reply.send(runtime.warmup());
            }
            Job::Shutdown => break,
        }
    }
}
