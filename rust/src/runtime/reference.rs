//! Pure-rust reference compute backend.
//!
//! When the PJRT runtime (the `xla` crate + AOT-compiled artifacts) is
//! unavailable — offline build environments, CI, machines without
//! `make artifacts` — the compute service falls back to this backend. It
//! implements the same artifact contract as the compiled graphs:
//!
//! * `sim_{A}x{N}` — inner-product scores, the exact semantics of the
//!   Pallas similarity kernel (`python/compile/kernels/ref.py::
//!   similarity_ref`), so every retrieval numeric is identical;
//! * `proj_{B}` — the hash-projection embedder `normalize(feats @ W + b)`
//!   (`projection_ref`), using the real weight blob when `artifacts/`
//!   exists and a deterministic seeded matrix otherwise;
//! * `enc_{B}` / `prefill_1` — deterministic stand-ins for the
//!   transformer graphs: token-hash embeddings (mean-pooled, normalized)
//!   and seeded logits. They preserve the properties the serving stack
//!   relies on (determinism, unit norm, token-overlap similarity) but NOT
//!   the compiled models' numerics — golden-parity tests require real
//!   artifacts and skip otherwise.
//!
//! Unlike PJRT (whose `Rc`-based handles pin all state to one executor
//! thread), this backend is plain `Sync` data and executes **on the
//! calling thread** — so the serving engine's worker pool scales query
//! throughput with cores instead of serializing on a compute channel.

use anyhow::{bail, Result};

use super::manifest::{InputKind, Manifest};
use super::service::Tensor;
use crate::data::Rng;

/// Seed for the deterministic projection weights when no artifact blob is
/// available. Changing it changes every embedding — keep it stable.
const PROJ_SEED: u64 = 0xED6E_0001;
/// Per-token seed salt for the encoder stand-in.
const TOK_SEED: u64 = 0xED6E_0002;
/// Seed salt for the prefill logits stand-in.
const PREFILL_SEED: u64 = 0xED6E_0003;

/// The reference backend: deterministic, thread-safe, allocation-light.
#[derive(Debug)]
pub struct RefCompute {
    dim: usize,
    vocab: usize,
    /// Projection weight, row-major `(vocab, dim)`.
    proj_w: Vec<f32>,
    /// Projection bias, `(dim,)`.
    proj_b: Vec<f32>,
}

impl RefCompute {
    pub fn new(manifest: &Manifest) -> RefCompute {
        let dim = manifest.dim;
        let vocab = manifest.vocab;
        let (proj_w, proj_b) = Self::projection_weights(manifest, vocab, dim);
        RefCompute {
            dim,
            vocab,
            proj_w,
            proj_b,
        }
    }

    /// Load the real projection weight blob when the artifacts directory
    /// has one (numerics then match the compiled `proj_*` graphs exactly,
    /// since projection is just `normalize(feats @ W + b)`); otherwise
    /// generate a fixed seeded matrix.
    fn projection_weights(manifest: &Manifest, vocab: usize, dim: usize) -> (Vec<f32>, Vec<f32>) {
        let total = vocab * dim + dim;
        for artifact in &manifest.artifacts {
            if !artifact.name.starts_with("proj_") {
                continue;
            }
            for input in artifact.inputs.iter().filter(|i| i.kind == InputKind::Weight) {
                if let Ok(theta) = manifest.read_weights(input) {
                    if theta.len() == total {
                        let w = theta[..vocab * dim].to_vec();
                        let b = theta[vocab * dim..].to_vec();
                        return (w, b);
                    }
                }
            }
        }
        let mut rng = Rng::new(PROJ_SEED);
        let scale = 1.0 / (dim as f64).sqrt();
        let w = (0..vocab * dim)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        let b = vec![0.0f32; dim];
        (w, b)
    }

    /// Execute one artifact by name. Shapes come from the tensors
    /// themselves, so every compiled bucket (`sim_1x128` … `sim_32x512`,
    /// `proj_1`/`proj_32`, `enc_1`/`enc_8`) routes through one
    /// implementation per family.
    pub fn run(&self, artifact: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        if artifact.starts_with("sim_") {
            self.run_sim(artifact, inputs)
        } else if artifact.starts_with("proj_") {
            self.run_projection(artifact, inputs)
        } else if artifact.starts_with("enc_") {
            self.run_encoder(artifact, inputs)
        } else if artifact == "prefill_1" {
            self.run_prefill(inputs)
        } else {
            bail!("reference backend: unknown artifact `{artifact}`")
        }
    }

    fn f32_input<'a>(artifact: &str, inputs: &'a [Tensor], i: usize) -> Result<(&'a [f32], &'a [usize])> {
        match inputs.get(i) {
            Some(Tensor::F32(d, s)) if s.len() == 2 => Ok((d.as_slice(), s.as_slice())),
            other => bail!("{artifact}: input {i} must be rank-2 f32, got {other:?}"),
        }
    }

    fn i32_input<'a>(artifact: &str, inputs: &'a [Tensor], i: usize) -> Result<(&'a [i32], &'a [usize])> {
        match inputs.get(i) {
            Some(Tensor::I32(d, s)) if s.len() == 2 => Ok((d.as_slice(), s.as_slice())),
            other => bail!("{artifact}: input {i} must be rank-2 i32, got {other:?}"),
        }
    }

    /// Row tile for the cache-blocked similarity kernel: 64 rows × 512
    /// dims × 4 bytes = 128 KiB of `rows` per tile, sized to stay
    /// resident in L2 while every query row streams over it.
    const SIM_TILE_ROWS: usize = 64;

    /// `sim_{A}x{N}`: inner products, row-major (A × N) output.
    ///
    /// Cache-blocked over the lane-reduction dot: the row matrix is
    /// walked in [`Self::SIM_TILE_ROWS`]-row tiles and every query row
    /// scores a whole tile before the next tile is touched, so for
    /// multi-query batches each tile of `rows` is loaded from memory
    /// once instead of A times. Each output element is still exactly one
    /// `vecmath::dot` call — the tiling permutes the *order* elements
    /// are computed in, never the reduction inside one, so results are
    /// bit-identical to the naive double loop.
    fn run_sim(&self, artifact: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (q, qs) = Self::f32_input(artifact, inputs, 0)?;
        let (rows, rs) = Self::f32_input(artifact, inputs, 1)?;
        let (a, d) = (qs[0], qs[1]);
        let n = rs[0];
        if d != self.dim || rs[1] != d || q.len() != a * d || rows.len() != n * d {
            bail!("{artifact}: shape mismatch (q {qs:?}, rows {rs:?})");
        }
        let mut out = vec![0.0f32; a * n];
        for j0 in (0..n).step_by(Self::SIM_TILE_ROWS) {
            let j1 = (j0 + Self::SIM_TILE_ROWS).min(n);
            for i in 0..a {
                let qi = &q[i * d..(i + 1) * d];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in j0..j1 {
                    orow[j] = crate::vecmath::dot(qi, &rows[j * d..(j + 1) * d]);
                }
            }
        }
        Ok(vec![out])
    }

    /// `proj_{B}`: `normalize(feats @ W + b)` — `projection_ref` exactly
    /// (eps 1e-6 inside the square root).
    fn run_projection(&self, artifact: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (feats, fs) = Self::f32_input(artifact, inputs, 0)?;
        let (b, vocab) = (fs[0], fs[1]);
        if vocab != self.vocab || feats.len() != b * vocab {
            bail!("{artifact}: shape mismatch {fs:?}");
        }
        let dim = self.dim;
        let mut out = vec![0.0f32; b * dim];
        for r in 0..b {
            let frow = &feats[r * vocab..(r + 1) * vocab];
            let orow = &mut out[r * dim..(r + 1) * dim];
            orow.copy_from_slice(&self.proj_b);
            // Bag-of-tokens features are sparse: skip zero counts. The
            // accumulation over nonzero tokens stays sequential (that
            // order is the numeric contract); `axpy` vectorizes the dim
            // axis, where per-element updates are independent and the
            // unroll is bit-exact.
            for (v, &f) in frow.iter().enumerate() {
                if f != 0.0 {
                    let wrow = &self.proj_w[v * dim..(v + 1) * dim];
                    crate::vecmath::axpy(f, wrow, orow);
                }
            }
            let norm = (orow.iter().map(|x| (x * x) as f64).sum::<f64>() + 1e-6).sqrt() as f32;
            for o in orow.iter_mut() {
                *o /= norm;
            }
        }
        Ok(vec![out])
    }

    /// `enc_{B}`: deterministic token-hash embeddings, mean-pooled over
    /// unmasked positions and L2-normalized.
    fn run_encoder(&self, artifact: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (ids, is) = Self::i32_input(artifact, inputs, 0)?;
        let (mask, ms) = Self::f32_input(artifact, inputs, 1)?;
        let (b, seq) = (is[0], is[1]);
        if ms != is || ids.len() != b * seq || mask.len() != b * seq {
            bail!("{artifact}: shape mismatch (ids {is:?}, mask {ms:?})");
        }
        let dim = self.dim;
        let mut out = vec![0.0f32; b * dim];
        for r in 0..b {
            let orow = &mut out[r * dim..(r + 1) * dim];
            for p in 0..seq {
                if mask[r * seq + p] <= 0.0 {
                    continue;
                }
                let tok = ids[r * seq + p];
                let mut rng = Rng::new(TOK_SEED ^ ((tok as u32 as u64) << 8));
                for o in orow.iter_mut() {
                    *o += rng.normal() as f32;
                }
            }
            let norm = crate::vecmath::l2_norm(orow).max(1e-6);
            for o in orow.iter_mut() {
                *o /= norm;
            }
        }
        Ok(vec![out])
    }

    /// `prefill_1`: deterministic logits seeded by the prompt ids.
    fn run_prefill(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (ids, is) = Self::i32_input("prefill_1", inputs, 0)?;
        if ids.len() != is[0] * is[1] {
            bail!("prefill_1: shape mismatch {is:?}");
        }
        // FNV-style fold of the prompt ids → one seed → vocab logits.
        let mut seed = PREFILL_SEED;
        for &t in ids {
            seed = seed
                .wrapping_mul(0x0000_0100_0000_01B3)
                .wrapping_add(t as u32 as u64);
        }
        let mut rng = Rng::new(seed);
        let logits = (0..self.vocab).map(|_| rng.normal() as f32).collect();
        Ok(vec![logits])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn backend() -> RefCompute {
        RefCompute::new(&Manifest::builtin(std::path::Path::new("/nonexistent")))
    }

    #[test]
    fn sim_is_exact_dot() {
        let b = backend();
        let dim = 256;
        let q: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        let mut rows = vec![0.0f32; 128 * dim];
        rows[..dim].copy_from_slice(&q); // row 0 = q
        let out = b
            .run(
                "sim_1x128",
                &[
                    Tensor::F32(q.clone(), vec![1, dim]),
                    Tensor::F32(rows, vec![128, dim]),
                ],
            )
            .unwrap();
        assert_eq!(out[0].len(), 128);
        let want: f32 = q.iter().map(|x| x * x).sum();
        assert!((out[0][0] - want).abs() < 1e-3);
        assert_eq!(out[0][1], 0.0);
    }

    #[test]
    fn projection_is_unit_norm_and_deterministic() {
        let b = backend();
        let vocab = 4096;
        let mut feats = vec![0.0f32; vocab];
        feats[17] = 2.0;
        feats[901] = 1.0;
        let run = |f: &RefCompute| {
            f.run("proj_1", &[Tensor::F32(feats.clone(), vec![1, vocab])])
                .unwrap()[0]
                .clone()
        };
        let a = run(&b);
        let c = run(&backend());
        assert_eq!(a.len(), 256);
        assert_eq!(a, c, "must be deterministic across instances");
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
    }

    #[test]
    fn encoder_similarity_tracks_token_overlap() {
        let b = backend();
        let seq = 64;
        let mk = |toks: &[i32]| {
            let mut ids = vec![0i32; seq];
            let mut mask = vec![0.0f32; seq];
            for (i, &t) in toks.iter().enumerate() {
                ids[i] = t;
                mask[i] = 1.0;
            }
            b.run(
                "enc_1",
                &[
                    Tensor::I32(ids, vec![1, seq]),
                    Tensor::F32(mask, vec![1, seq]),
                ],
            )
            .unwrap()[0]
                .clone()
        };
        let x = mk(&[5, 9, 12, 40]);
        let near = mk(&[5, 9, 12, 41]);
        let far = mk(&[100, 200, 300, 400]);
        let dot = |a: &[f32], c: &[f32]| crate::vecmath::dot(a, c);
        assert!((dot(&x, &x) - 1.0).abs() < 1e-3);
        assert!(dot(&x, &near) > dot(&x, &far));
    }

    #[test]
    fn prefill_logits_deterministic_per_prompt() {
        let b = backend();
        let seq = 256;
        let mut ids = vec![0i32; seq];
        ids[0] = 2;
        ids[1] = 77;
        let run = |ids: Vec<i32>| b.run("prefill_1", &[Tensor::I32(ids, vec![1, seq])]).unwrap();
        let a = run(ids.clone());
        let c = run(ids.clone());
        assert_eq!(a[0], c[0]);
        assert_eq!(a[0].len(), 4096);
        let mut other = ids.clone();
        other[1] = 78;
        let d = run(other);
        assert_ne!(a[0], d[0]);
    }

    #[test]
    fn unknown_artifact_rejected() {
        let b = backend();
        assert!(b.run("nope_3", &[]).is_err());
    }

    fn random_mat(rng: &mut Rng, rows: usize, dim: usize) -> Vec<f32> {
        (0..rows * dim).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn tiled_sim_bit_identical_to_naive_loop() {
        // Property: across shapes that hit partial tiles (n not a
        // multiple of SIM_TILE_ROWS) and multi-query batches, the
        // cache-blocked kernel equals the retired naive double loop
        // bit for bit — same dot per element, different visit order.
        let b = backend();
        let dim = b.dim;
        let mut rng = Rng::new(crate::testutil::test_seed(0x51A));
        for &(a, n) in &[(1usize, 1usize), (1, 63), (1, 64), (1, 65), (4, 128), (3, 200), (8, 257)] {
            let q = random_mat(&mut rng, a, dim);
            let rows = random_mat(&mut rng, n, dim);
            let got = &b
                .run(
                    "sim_1x128",
                    &[
                        Tensor::F32(q.clone(), vec![a, dim]),
                        Tensor::F32(rows.clone(), vec![n, dim]),
                    ],
                )
                .unwrap()[0];
            let mut want = Vec::with_capacity(a * n);
            for i in 0..a {
                let qi = &q[i * dim..(i + 1) * dim];
                for j in 0..n {
                    want.push(crate::vecmath::dot(qi, &rows[j * dim..(j + 1) * dim]));
                }
            }
            assert_eq!(got.len(), want.len(), "shape {a}x{n}");
            for (e, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "shape {a}x{n} elem {e}");
            }
        }
    }

    #[test]
    fn axpy_projection_bit_identical_to_scalar_accumulation() {
        // Property: the vectorized projection equals the retired
        // elementwise inner loop bit for bit, including the f64 norm.
        let b = backend();
        let (vocab, dim) = (b.vocab, b.dim);
        let mut rng = Rng::new(crate::testutil::test_seed(0xA8A));
        for case in 0..6 {
            let mut feats = vec![0.0f32; vocab];
            for _ in 0..rng.below(40) + 1 {
                feats[rng.below(vocab)] = (rng.below(5) + 1) as f32;
            }
            let got = &b
                .run("proj_1", &[Tensor::F32(feats.clone(), vec![1, vocab])])
                .unwrap()[0];
            let mut want = b.proj_b.clone();
            for (v, &f) in feats.iter().enumerate() {
                if f != 0.0 {
                    let wrow = &b.proj_w[v * dim..(v + 1) * dim];
                    for (o, w) in want.iter_mut().zip(wrow) {
                        *o += f * w;
                    }
                }
            }
            let norm = (want.iter().map(|x| (x * x) as f64).sum::<f64>() + 1e-6).sqrt() as f32;
            for o in want.iter_mut() {
                *o /= norm;
            }
            for (e, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "case {case} elem {e}");
            }
        }
    }
}
