//! Synthetic BEIR-like corpus generator.
//!
//! Substitution for the real BEIR datasets (DESIGN.md §3): a generative
//! topic model producing corpora whose *retrieval-relevant statistics*
//! match Table 2 —
//!
//! * topic (→ natural cluster) sizes are lognormal, giving the tail-heavy
//!   cluster-size distribution of Fig. 5;
//! * chunks from one topic share a topic vocabulary, so embeddings cluster
//!   by topic under any reasonable embedder;
//! * a fraction of chunks are near-duplicates, giving each query a small
//!   ground-truth relevant set (BEIR-style qrels) for precision/recall.

use crate::config::DatasetProfile;
use crate::data::rng::Rng;

/// One data chunk (the unit the paper indexes, embeds and retrieves).
#[derive(Debug, Clone)]
pub struct Chunk {
    pub id: u32,
    pub topic: u32,
    /// Duplicate-group id: chunks with the same group are near-duplicates
    /// of each other (the qrel unit).
    pub group: u32,
    pub text: String,
}

impl Chunk {
    pub fn chars(&self) -> u64 {
        self.text.len() as u64
    }
}

/// A generated corpus plus its topic structure.
#[derive(Debug)]
pub struct Corpus {
    pub name: String,
    pub chunks: Vec<Chunk>,
    pub n_topics: usize,
}

impl Corpus {
    /// Deterministically generate the corpus described by `profile`.
    pub fn generate(profile: &DatasetProfile) -> Corpus {
        let mut rng = Rng::new(profile.seed);

        // Topic sizes: lognormal, tail-heavy, normalized to n_chunks.
        let raw: Vec<f64> = (0..profile.n_topics)
            .map(|_| rng.lognormal(0.0, profile.cluster_sigma))
            .collect();
        let total: f64 = raw.iter().sum();
        let mut sizes: Vec<usize> = raw
            .iter()
            .map(|w| ((w / total) * profile.n_chunks as f64).round() as usize)
            .collect();
        // Fix rounding drift; every topic keeps at least one chunk.
        for s in sizes.iter_mut() {
            *s = (*s).max(1);
        }
        let mut assigned: usize = sizes.iter().sum();
        while assigned > profile.n_chunks {
            let i = (0..sizes.len()).max_by_key(|&i| sizes[i]).unwrap();
            sizes[i] -= 1;
            assigned -= 1;
        }
        while assigned < profile.n_chunks {
            let i = rng.below(sizes.len());
            sizes[i] += 1;
            assigned += 1;
        }

        // Per-topic vocabulary + shared common vocabulary.
        let topic_vocab_size = 48;
        let common_vocab_size = 256;
        let common: Vec<String> = (0..common_vocab_size).map(|k| format!("c{k}")).collect();

        let mut chunks: Vec<Chunk> = Vec::with_capacity(profile.n_chunks);
        let mut id: u32 = 0;
        for (topic, &size) in sizes.iter().enumerate() {
            let tv: Vec<String> = (0..topic_vocab_size)
                .map(|k| format!("t{topic}w{k}"))
                .collect();
            let mut topic_rng = rng.fork(topic as u64);
            let first_of_topic = id;
            for j in 0..size {
                // ~18% of non-initial chunks are near-duplicates of an
                // earlier chunk in the topic: the qrel groups.
                let dup_of = if j > 0 && topic_rng.f64() < 0.18 {
                    let prev = first_of_topic + topic_rng.below(j) as u32;
                    Some(chunks[prev as usize].clone())
                } else {
                    None
                };
                let chunk = match dup_of {
                    Some(orig) => Chunk {
                        id,
                        topic: topic as u32,
                        group: orig.group,
                        text: mutate_text(&orig.text, &tv, &mut topic_rng),
                    },
                    None => Chunk {
                        id,
                        topic: topic as u32,
                        group: id,
                        text: gen_text(
                            profile.chunk_chars_mean,
                            &tv,
                            &common,
                            &mut topic_rng,
                        ),
                    },
                };
                chunks.push(chunk);
                id += 1;
            }
        }

        Corpus {
            name: profile.name.clone(),
            chunks,
            n_topics: profile.n_topics,
        }
    }

    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    pub fn total_chars(&self) -> u64 {
        self.chunks.iter().map(|c| c.chars()).sum()
    }

    /// All chunk ids sharing `group` (the relevant set for a query built
    /// from any chunk of that group).
    pub fn group_members(&self, group: u32) -> Vec<u32> {
        self.chunks
            .iter()
            .filter(|c| c.group == group)
            .map(|c| c.id)
            .collect()
    }

    pub fn texts(&self) -> Vec<&str> {
        self.chunks.iter().map(|c| c.text.as_str()).collect()
    }
}

/// Fresh chunk text: ~70% topic words, ~30% common words, until the target
/// character budget (±30%) is met.
fn gen_text(chars_mean: usize, topic_vocab: &[String], common: &[String], rng: &mut Rng) -> String {
    let target = (chars_mean as f64 * (0.7 + 0.6 * rng.f64())) as usize;
    let mut text = String::with_capacity(target + 16);
    while text.len() < target {
        let w = if rng.f64() < 0.7 {
            &topic_vocab[rng.below(topic_vocab.len())]
        } else {
            &common[rng.below(common.len())]
        };
        if !text.is_empty() {
            text.push(' ');
        }
        text.push_str(w);
    }
    text
}

/// Near-duplicate: resample ~15% of the words from the topic vocabulary.
fn mutate_text(orig: &str, topic_vocab: &[String], rng: &mut Rng) -> String {
    let words: Vec<&str> = orig.split(' ').collect();
    let mut out = String::with_capacity(orig.len() + 8);
    for w in words {
        if !out.is_empty() {
            out.push(' ');
        }
        if rng.f64() < 0.15 {
            out.push_str(&topic_vocab[rng.below(topic_vocab.len())]);
        } else {
            out.push_str(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;

    fn tiny() -> Corpus {
        Corpus::generate(&DatasetProfile::tiny())
    }

    #[test]
    fn chunk_count_matches_profile() {
        let c = tiny();
        assert_eq!(c.len(), DatasetProfile::tiny().n_chunks);
    }

    #[test]
    fn deterministic() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.chunks.len(), b.chunks.len());
        for (x, y) in a.chunks.iter().zip(&b.chunks) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.topic, y.topic);
            assert_eq!(x.group, y.group);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut p = DatasetProfile::tiny();
        p.seed = 999;
        let a = tiny();
        let b = Corpus::generate(&p);
        assert_ne!(a.chunks[0].text, b.chunks[0].text);
    }

    #[test]
    fn topics_cover_all_chunks_in_order() {
        let c = tiny();
        let mut last_topic = 0;
        for ch in &c.chunks {
            assert!(ch.topic >= last_topic, "topics must be contiguous runs");
            last_topic = ch.topic;
            assert!((ch.topic as usize) < c.n_topics);
        }
    }

    #[test]
    fn topic_sizes_are_tail_heavy() {
        let mut p = DatasetProfile::tiny();
        p.n_chunks = 4096;
        p.n_topics = 64;
        p.cluster_sigma = 1.0;
        let c = Corpus::generate(&p);
        let mut sizes = vec![0usize; p.n_topics];
        for ch in &c.chunks {
            sizes[ch.topic as usize] += 1;
        }
        sizes.sort_unstable();
        let median = sizes[sizes.len() / 2] as f64;
        let max = *sizes.last().unwrap() as f64;
        assert!(max / median > 3.0, "max/median = {}", max / median);
    }

    #[test]
    fn duplicate_groups_exist_and_share_topic() {
        let c = tiny();
        let mut dup_chunks = 0;
        for ch in &c.chunks {
            if ch.group != ch.id {
                dup_chunks += 1;
                let orig = &c.chunks[ch.group as usize];
                assert_eq!(orig.topic, ch.topic);
            }
        }
        assert!(dup_chunks > 10, "only {dup_chunks} duplicates");
    }

    #[test]
    fn group_members_includes_original_and_dups() {
        let c = tiny();
        let dup = c.chunks.iter().find(|ch| ch.group != ch.id).unwrap();
        let members = c.group_members(dup.group);
        assert!(members.contains(&dup.id));
        assert!(members.contains(&dup.group));
        assert!(members.len() >= 2);
    }

    #[test]
    fn chunk_chars_near_mean() {
        let c = tiny();
        let mean = c.total_chars() as f64 / c.len() as f64;
        let target = DatasetProfile::tiny().chunk_chars_mean as f64;
        assert!(
            (mean - target).abs() / target < 0.25,
            "mean {mean} vs target {target}"
        );
    }
}
