//! Deterministic PRNG + samplers for workload generation.
//!
//! Self-contained (no `rand` dependency) so corpus/query generation is
//! bit-reproducible across runs and platforms — every figure in
//! EXPERIMENTS.md regenerates from a seed.

/// xoshiro256** — fast, high-quality, and tiny.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding per xoshiro reference implementation.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Derive an independent stream (for per-cluster / per-query streams).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

/// Zipf(θ) sampler over ranks [0, n) — models the skewed cluster access
/// pattern the paper observes (Table 2 reuse ratios, §3.2 "highly skewed").
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(theta);
            cdf.push(acc);
        }
        let norm = acc;
        for v in &mut cdf {
            *v /= norm;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_is_heavy_tailed() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(3.0, 1.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        let p99 = xs[n * 99 / 100];
        assert!(p99 / median > 5.0, "p99/median = {}", p99 / median);
    }

    #[test]
    fn zipf_skew() {
        let z = Zipf::new(100, 1.0);
        let mut r = Rng::new(17);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
        // rank-0 mass should be close to 1/H(100) ≈ 0.192
        let p0 = counts[0] as f64 / 50_000.0;
        assert!((p0 - 0.192).abs() < 0.03, "p0 = {p0}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(19);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
