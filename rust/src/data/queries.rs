//! Query workload generator.
//!
//! Reproduces the access statistics of Table 2: each dataset's workload
//! has a target *reuse ratio* (total cluster accesses / unique clusters
//! accessed). We realize it by drawing each query's target chunk from a
//! fixed pool of `n_queries / reuse_ratio` hot chunks under a Zipf skew —
//! the same "small subset of clusters is searched repeatedly" phenomenon
//! the paper exploits with its embedding cache (§4.2).

use crate::config::DatasetProfile;
use crate::data::corpus::Corpus;
use crate::data::rng::{Rng, Zipf};

/// One evaluation query with BEIR-style ground truth.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u32,
    pub text: String,
    /// The chunk this query was derived from.
    pub target_chunk: u32,
    /// Ground-truth relevant chunk ids (the target's duplicate group).
    pub relevant: Vec<u32>,
}

/// A full query workload.
#[derive(Debug)]
pub struct Workload {
    pub queries: Vec<Query>,
}

impl Workload {
    /// Deterministically generate the workload for `profile` over `corpus`.
    pub fn generate(profile: &DatasetProfile, corpus: &Corpus) -> Workload {
        let mut rng = Rng::new(profile.seed ^ 0xC0FFEE);
        let n_queries = profile.n_queries;
        // Hot-chunk pool sized to hit the target reuse ratio. The pool is
        // *topic-skewed* (hot topics contribute many hot chunks): user
        // interests concentrate, which is what gives the paper's workloads
        // their cluster-level access locality (§3.2 "highly skewed",
        // Table 2 reuse) — the premise of the embedding cache.
        let uniques = ((n_queries as f64 / profile.reuse_ratio).round() as usize)
            .clamp(1, corpus.len());
        let topic_zipf = Zipf::new(corpus.n_topics, 1.3);
        let mut topic_chunks: Vec<Vec<u32>> = vec![Vec::new(); corpus.n_topics];
        for c in &corpus.chunks {
            topic_chunks[c.topic as usize].push(c.id);
        }
        let mut pool_set = std::collections::HashSet::with_capacity(uniques);
        let mut pool: Vec<usize> = Vec::with_capacity(uniques);
        let mut attempts = 0;
        while pool.len() < uniques && attempts < uniques * 50 {
            attempts += 1;
            let t = topic_zipf.sample(&mut rng);
            let members = &topic_chunks[t];
            if members.is_empty() {
                continue;
            }
            let pick = members[rng.below(members.len())] as usize;
            if pool_set.insert(pick) {
                pool.push(pick);
            }
        }
        // Rare fallback: fill any shortfall uniformly.
        let mut next = 0usize;
        while pool.len() < uniques {
            if pool_set.insert(next) {
                pool.push(next);
            }
            next += 1;
        }
        let zipf = Zipf::new(uniques, 1.0);

        let mut queries = Vec::with_capacity(n_queries);
        for qid in 0..n_queries {
            let target = pool[zipf.sample(&mut rng)] as u32;
            let chunk = &corpus.chunks[target as usize];
            let text = query_text(&chunk.text, &mut rng);
            queries.push(Query {
                id: qid as u32,
                text,
                target_chunk: target,
                relevant: corpus.group_members(chunk.group),
            });
        }
        Workload { queries }
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// Measured reuse ratio at the *target chunk* level
    /// (total queries / unique targets), the analogue of Table 2's
    /// total/unique cluster accesses.
    pub fn reuse_ratio(&self) -> f64 {
        let unique: std::collections::HashSet<u32> =
            self.queries.iter().map(|q| q.target_chunk).collect();
        self.queries.len() as f64 / unique.len().max(1) as f64
    }
}

/// Query text: 5–9 distinctive words sampled from the chunk plus up to two
/// generic "question" words, shuffled.
fn query_text(chunk_text: &str, rng: &mut Rng) -> String {
    let words: Vec<&str> = chunk_text.split(' ').filter(|w| !w.is_empty()).collect();
    let n = rng.range(5, 10).min(words.len().max(1));
    let mut picks: Vec<String> = (0..n)
        .map(|_| words[rng.below(words.len())].to_string())
        .collect();
    let fillers = ["what", "how", "why", "which", "who"];
    for _ in 0..rng.below(3) {
        picks.push(fillers[rng.below(fillers.len())].to_string());
    }
    rng.shuffle(&mut picks);
    picks.join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;

    fn setup() -> (DatasetProfile, Corpus) {
        let p = DatasetProfile::tiny();
        let c = Corpus::generate(&p);
        (p, c)
    }

    #[test]
    fn query_count_matches_profile() {
        let (p, c) = setup();
        let w = Workload::generate(&p, &c);
        assert_eq!(w.len(), p.n_queries);
    }

    #[test]
    fn deterministic() {
        let (p, c) = setup();
        let a = Workload::generate(&p, &c);
        let b = Workload::generate(&p, &c);
        for (x, y) in a.queries.iter().zip(&b.queries) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.target_chunk, y.target_chunk);
        }
    }

    #[test]
    fn reuse_ratio_near_target() {
        // Use a bigger workload for a stable estimate.
        let mut p = DatasetProfile::tiny();
        p.n_chunks = 2000;
        p.n_queries = 1000;
        p.reuse_ratio = 2.5;
        let c = Corpus::generate(&p);
        let w = Workload::generate(&p, &c);
        let r = w.reuse_ratio();
        // Zipf sampling leaves some pool members unhit, so measured reuse
        // is ≥ target but same order.
        assert!(r >= 2.0 && r <= 4.5, "reuse ratio {r}");
    }

    #[test]
    fn relevant_sets_contain_target() {
        let (p, c) = setup();
        let w = Workload::generate(&p, &c);
        for q in &w.queries {
            assert!(q.relevant.contains(&q.target_chunk));
            assert!(!q.relevant.is_empty());
        }
    }

    #[test]
    fn query_words_come_from_target_chunk() {
        let (p, c) = setup();
        let w = Workload::generate(&p, &c);
        let fillers = ["what", "how", "why", "which", "who"];
        let mut from_chunk = 0;
        let mut total = 0;
        for q in w.queries.iter().take(20) {
            let chunk_words: std::collections::HashSet<&str> =
                c.chunks[q.target_chunk as usize].text.split(' ').collect();
            for w in q.text.split(' ') {
                total += 1;
                if chunk_words.contains(w) || fillers.contains(&w) {
                    from_chunk += 1;
                }
            }
        }
        assert_eq!(from_chunk, total, "query words must come from the chunk");
    }

    #[test]
    fn skewed_access_pattern() {
        // The most popular target must be hit far more than the median —
        // the skew the paper's cache exploits.
        let mut p = DatasetProfile::tiny();
        p.n_queries = 500;
        p.reuse_ratio = 4.0;
        let c = Corpus::generate(&p);
        let w = Workload::generate(&p, &c);
        let mut counts = std::collections::HashMap::new();
        for q in &w.queries {
            *counts.entry(q.target_chunk).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max >= 10, "hottest target only hit {max} times");
    }
}
