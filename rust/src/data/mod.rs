//! Workload substrate: deterministic synthetic BEIR-like corpora and query
//! workloads (DESIGN.md §3 documents the substitution for the real BEIR
//! datasets).

pub mod corpus;
pub mod queries;
pub mod rng;

pub use corpus::{Chunk, Corpus};
pub use queries::{Query, Workload};
pub use rng::{Rng, Zipf};
