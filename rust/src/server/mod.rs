//! Request server: a line-delimited JSON protocol over TCP.
//!
//! ## Concurrency
//!
//! The crate cache has no async runtime, so the server is thread-based:
//! one acceptor + one handler thread per connection, all submitting work
//! to a fixed **worker pool** that executes requests against one shared
//! [`Engine`]. Queries run read-parallel (the engine's index takes only
//! a read lease per search). `insert`/`remove` go through
//! [`Engine::insert`] / [`Engine::remove`]: on the (default for `serve`)
//! sharded index they write-lease only the owning shard, so a worker
//! inserting into shard A overlaps with workers querying shards B..N; on
//! a single-shard index they fall back to the exclusive engine lease,
//! draining in-flight searches first. The pool bounds concurrent engine
//! work regardless of how many clients connect.
//!
//! Protocol (one JSON object per line):
//!   {"op":"query","text":"..."}      → hits + latency breakdown
//!   {"op":"insert","text":"..."}     → {"id": N, "cluster": C}
//!   {"op":"remove","id":N}           → {"removed": bool}
//!   {"op":"stats"}                   → serving metrics
//!   {"op":"ping"}                    → {"ok": true}
//!   {"op":"shutdown"}                → {"ok": true}, then the server stops
//!
//! Shutdown dispatches on the *parsed* `op` — a query whose text merely
//! contains the word "shutdown" is served like any other query.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::Engine;
use crate::embedding::Embedder;
use crate::index::{EdgeIndex, ShardedEdgeIndex};
use crate::json::{self, Value};
use crate::simtime::Component;

// ---------------------------------------------------------------------------
// Worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Cloneable submission handle to the worker pool.
#[derive(Clone)]
pub struct PoolHandle {
    tx: mpsc::Sender<Job>,
}

impl PoolHandle {
    fn submit(&self, job: Job) -> Result<()> {
        self.tx
            .send(job)
            .map_err(|_| anyhow::anyhow!("worker pool is shut down"))
    }
}

/// Fixed-size worker pool over a shared job queue. Workers exit once the
/// queue closes (every submission handle dropped) and it drains; the
/// threads are detached so dropping the pool never blocks on a client
/// that is still connected.
struct WorkerPool {
    handle: PoolHandle,
}

impl WorkerPool {
    fn new(n: usize) -> WorkerPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..n.max(1) {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("edgerag-worker-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only for the dequeue.
                    let job = match rx.lock() {
                        Ok(guard) => match guard.recv() {
                            Ok(job) => job,
                            Err(_) => break,
                        },
                        Err(_) => break, // queue mutex poisoned: stop cleanly
                    };
                    // Panic isolation: a panicking request must fail that
                    // one response (the handler sees its reply channel
                    // drop), not kill the worker and shrink the pool.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                })
                .expect("spawning worker thread");
        }
        WorkerPool {
            handle: PoolHandle { tx },
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Shared server state. Inserted chunks' text goes to the engine's
/// shared text store (inside [`Engine::insert`], which pushes the text
/// *before* the index mutation so ids and index state stay consistent).
pub struct ServerState {
    pub engine: Arc<Engine>,
    pub embedder: Embedder,
    running: AtomicBool,
}

/// The TCP request server: acceptor + per-connection handler threads
/// over a fixed worker pool and one shared [`Engine`].
pub struct Server {
    state: Arc<ServerState>,
    pool: WorkerPool,
    listener: TcpListener,
}

/// Default worker-pool size: one worker per available core, clamped to a
/// sensible serving range.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 16)
}

impl Server {
    /// Bind on `addr` (e.g. "127.0.0.1:7313") with the default pool size.
    pub fn bind(addr: &str, engine: Engine, embedder: Embedder) -> Result<Server> {
        Self::bind_with_workers(addr, engine, embedder, default_workers())
    }

    /// Bind with an explicit worker-pool size.
    pub fn bind_with_workers(
        addr: &str,
        engine: Engine,
        embedder: Embedder,
        workers: usize,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            state: Arc::new(ServerState {
                engine: Arc::new(engine),
                embedder,
                running: AtomicBool::new(true),
            }),
            pool: WorkerPool::new(workers),
            listener,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `shutdown` op (blocking).
    pub fn run(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if !self.state.running.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let state = self.state.clone();
            let pool = self.pool.handle.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &state, &pool);
            });
        }
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, state: &Arc<ServerState>, pool: &PoolHandle) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, shutdown) = match serve_request(trimmed, state, pool) {
            Ok(pair) => pair,
            Err(e) => (
                Value::object(vec![("error", Value::str(format!("{e:#}")))]),
                false,
            ),
        };
        writeln!(out, "{response}")?;
        if shutdown {
            state.running.store(false, Ordering::SeqCst);
            // poke the acceptor loop awake
            let _ = TcpStream::connect(out.local_addr()?);
            return Ok(());
        }
    }
}

/// Parse one request line and execute it. Returns the response plus
/// whether this request asked the server to shut down (decided on the
/// parsed `op`, never on raw request text).
fn serve_request(
    line: &str,
    state: &Arc<ServerState>,
    pool: &PoolHandle,
) -> Result<(Value, bool)> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    let op = req
        .req("op")?
        .as_str()
        .context("op must be a string")?
        .to_string();
    // Control ops answered inline — they must not queue behind work.
    if op == "ping" {
        return Ok((Value::object(vec![("ok", true.into())]), false));
    }
    if op == "shutdown" {
        return Ok((Value::object(vec![("ok", true.into())]), true));
    }
    // Everything else executes on the worker pool: N workers run N
    // queries concurrently against the shared engine.
    let (reply_tx, reply_rx) = mpsc::channel();
    let state = state.clone();
    pool.submit(Box::new(move || {
        let _ = reply_tx.send(dispatch(&op, &req, &state));
    }))?;
    let response = reply_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("worker dropped the request"))??;
    Ok((response, false))
}

fn dispatch(op: &str, req: &Value, state: &ServerState) -> Result<Value> {
    match op {
        "query" => {
            let text = req.req("text")?.as_str().context("text")?;
            // Read-parallel: `handle` takes &self; only the vector search
            // holds the index read lease.
            let out = state.engine.handle(text)?;
            let hits = Value::array(out.hits.iter().map(|&(id, score)| {
                Value::object(vec![
                    ("chunk", id.into()),
                    ("score", (score as f64).into()),
                ])
            }));
            Ok(Value::object(vec![
                ("hits", hits),
                ("retrieval_ms", out.retrieval.as_millis_f64().into()),
                ("ttft_ms", out.ttft.as_millis_f64().into()),
                (
                    "embed_gen_ms",
                    out.breakdown.get(Component::EmbedGen).as_millis_f64().into(),
                ),
                ("prompt_tokens", out.prompt_tokens.into()),
                ("cache_hits", out.events.cache_hits.into()),
                ("generated", out.events.generated.into()),
                ("loaded", out.events.loaded.into()),
                ("wall_us", (out.wall.as_micros() as u64).into()),
            ]))
        }
        "insert" => {
            let text = req.req("text")?.as_str().context("text")?;
            // Shard-scoped on the sharded index (only the owning shard's
            // write lease — queries to other shards keep flowing),
            // engine-exclusive on a single-shard index.
            let (id, cluster) = state.engine.insert(text)?;
            Ok(Value::object(vec![
                ("id", id.into()),
                ("cluster", cluster.into()),
            ]))
        }
        "remove" => {
            let id = req.req("id")?.as_u64().context("id")? as u32;
            let removed = state.engine.remove(id)?;
            Ok(Value::object(vec![("removed", removed.into())]))
        }
        "stats" => {
            // Fully read-only: metrics snapshots + a shared index lease.
            let m = state.engine.metrics();
            let queries = m.queries();
            let retrieval = m.retrieval();
            let ttft = m.ttft();
            let (resident, hit_rate, threshold, shards) = {
                let index = state.engine.index();
                let resident = index.resident_bytes();
                if let Some(e) = index.as_any().downcast_ref::<EdgeIndex>() {
                    (
                        resident,
                        e.cache_stats().map(|s| s.hit_rate()).unwrap_or(0.0),
                        e.threshold_ms(),
                        None,
                    )
                } else if let Some(sh) = index.as_any().downcast_ref::<ShardedEdgeIndex>() {
                    // Per-shard rows: where probes/inserts landed, each
                    // shard's threshold and cache occupancy.
                    let rows = Value::array(sh.shard_stats().into_iter().map(|s| {
                        Value::object(vec![
                            ("shard", s.shard.into()),
                            ("clusters", s.clusters.into()),
                            ("probes", s.probes.into()),
                            ("cache_hits", s.cache_hits.into()),
                            ("generated", s.generated.into()),
                            ("loaded", s.loaded.into()),
                            ("inserts", s.inserts.into()),
                            ("removes", s.removes.into()),
                            ("threshold_ms", s.threshold_ms.into()),
                            ("cache_used_bytes", s.cache_used_bytes.into()),
                        ])
                    }));
                    (
                        resident,
                        sh.cache_stats().map(|s| s.hit_rate()).unwrap_or(0.0),
                        sh.threshold_ms(),
                        Some(rows),
                    )
                } else {
                    (resident, 0.0, 0.0, None)
                }
            };
            let mut fields = vec![
                ("queries", queries.into()),
                ("retrieval_p50_ms", retrieval.percentile(50.0).as_millis_f64().into()),
                ("retrieval_p95_ms", retrieval.percentile(95.0).as_millis_f64().into()),
                ("ttft_p50_ms", ttft.percentile(50.0).as_millis_f64().into()),
                ("ttft_p95_ms", ttft.percentile(95.0).as_millis_f64().into()),
                ("resident_bytes", resident.into()),
                ("cache_hit_rate", hit_rate.into()),
                ("threshold_ms", threshold.into()),
            ];
            if let Some(rows) = shards {
                fields.push(("shards", rows));
            }
            Ok(Value::object(fields))
        }
        other => anyhow::bail!("unknown op `{other}`"),
    }
}

/// Minimal blocking client for the line-JSON protocol (used by the CLI and
/// tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a serving endpoint (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send one request object and read its one-line response.
    pub fn call(&mut self, request: &Value) -> Result<Value> {
        writeln!(self.writer, "{request}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Convenience wrapper for the `query` op.
    pub fn query(&mut self, text: &str) -> Result<Value> {
        self.call(&Value::object(vec![
            ("op", Value::str("query")),
            ("text", Value::str(text)),
        ]))
    }
}
