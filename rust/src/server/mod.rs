//! Request server: a line-delimited JSON protocol over TCP.
//!
//! ## Concurrency
//!
//! The crate has no async runtime, so the front end is an **event-driven
//! reactor** ([`reactor`], Unix): one thread runs every connection
//! through a non-blocking `poll(2)` readiness loop and a per-connection
//! state machine (read buffer → line parse → submit to the bounded
//! admission queue → pending → write buffer). Requests execute on a
//! fixed **worker pool** (the shared [`crate::pool`] utility) against
//! one shared [`Engine`]; workers deliver finished responses through a
//! completion queue plus a wake pipe — no thread ever parks per
//! connection or per request, so an idle keep-alive connection costs a
//! buffer, not a thread. The pool's admission queue is **bounded**
//! (`max_inflight` from the retrieval config): submissions beyond
//! workers + queued capacity are rejected immediately with an
//! "overloaded" error instead of queueing without limit. Non-Unix hosts
//! (and the `connection_sweep` benchmark baseline) fall back to the
//! PR 1-era thread-per-connection front end ([`Server::run_threaded`]).
//!
//! ## Deadlines
//!
//! Every query is stamped with a deadline at admission
//! (`retrieval.deadline_us`, default `4 × slow_query_us` — the
//! `--deadline-us` serve knob). A query still queued — in the worker
//! pool or inside a batch stage — when its deadline expires is **shed**
//! with a distinct "deadline exceeded" error instead of executed, and
//! the batch scheduler closes partial batches no later than their
//! earliest rider's deadline. Sheds are counted server-side
//! (`deadline_shed`) and per stage (`shed`). Queries that do execute
//! return bit-identical results whether or not deadlines are armed.
//!
//! With batching enabled (the `serve` default; `--batching false` or
//! `RetrievalConfig::batching = false` disables it), queries flow
//! through the cross-query batch scheduler ([`crate::sched`]): worker
//! threads submit embedding/probe work items to per-stage queues, fused
//! kernel calls serve whole batches, and each query's cluster walks,
//! prefill and cache commit run back on its worker (stage 3). Results
//! are bit-identical to the unbatched path. `insert`/`remove` go through
//! [`Engine::insert`] / [`Engine::remove`]: on an index that supports
//! concurrent updates (the sharded default) they write-lease only the
//! owning shard; otherwise they fall back to the exclusive engine lease.
//!
//! Protocol (one JSON object per line):
//!   {"op":"query","text":"..."}      → hits + latency breakdown
//!   {"op":"insert","text":"..."}     → {"id": N, "cluster": C}
//!   {"op":"remove","id":N}           → {"removed": bool}
//!   {"op":"stats"}                   → serving metrics (+ scheduler
//!                                      stage stats when batching is on)
//!   {"op":"shard-stats"}             → just the per-shard load rows
//!                                      (error on an unsharded index)
//!   {"op":"rebalance"}               → run one cross-shard rebalance
//!                                      round; reports moves + load
//!                                      spread (all-zero when unsharded)
//!   {"op":"reshard","shards":N}      → grow/shrink the live shard count
//!                                      to N (clamped to the serve
//!                                      `--shards-min/--shards-max`
//!                                      bounds; error on an unsharded
//!                                      index)
//!   {"op":"trace"}                   → recent + slow trace summaries
//!                                      (tracing enabled); with "id": one
//!                                      trace's full span tree
//!   {"op":"metrics"}                 → {"body": "..."} — every counter,
//!                                      histogram, shard row and WAL/sched/
//!                                      tracer stat in Prometheus text
//!                                      exposition format
//!   {"op":"ping"}                    → {"ok": true}
//!   {"op":"shutdown"}                → {"ok": true}, then the server stops
//!
//! With tracing enabled (`--trace`, the `serve` default), each `query`/
//! `insert` response carries a `trace_id` field resolvable via the
//! `trace` op while the trace is still in the bounded rings.
//!
//! Shutdown dispatches on the *parsed* `op` — a query whose text merely
//! contains the word "shutdown" is served like any other query.

#[cfg(unix)]
mod reactor;

use std::fmt::Write as _;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::RetrievalConfig;
use crate::coordinator::metrics::LatencySeries;
use crate::coordinator::Engine;
use crate::embedding::Embedder;
use crate::json::{self, Value};
use crate::pool::{PoolHandle, SubmitError, WorkerPool};
use crate::sched::{BatchScheduler, SchedConfig, StageSnapshot};
use crate::simtime::Component;
use crate::trace::{self, QueryTrace, TagValue, Tracer};

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Shared server state. Inserted chunks' text goes to the engine's
/// shared text store (inside [`Engine::insert`], which pushes the text
/// *before* the index mutation so ids and index state stay consistent).
pub struct ServerState {
    pub engine: Arc<Engine>,
    pub embedder: Embedder,
    /// The cross-query batch scheduler; None serves the unbatched path.
    sched: Option<Arc<BatchScheduler>>,
    /// Query-scoped tracing plane; None leaves the record sites dark
    /// (one relaxed load per site).
    tracer: Option<Arc<Tracer>>,
    running: AtomicBool,
    /// Per-query deadline stamped at admission; None when the resolved
    /// deadline is 0 or too large to represent (deadlines disabled).
    deadline: Option<Duration>,
    /// The resolved deadline in µs (0 = disabled), for stats/errors.
    deadline_us: u64,
    /// Requests turned away because the admission queue was full —
    /// server-level, so overload is visible with or without batching.
    rejected: AtomicU64,
    /// Queries shed at worker dequeue because their deadline had already
    /// expired (stage-level sheds are counted per stage in `sched`).
    deadline_shed: AtomicU64,
    /// Elastic-topology floor for the `reshard` op (≥ 1).
    shards_min: usize,
    /// Elastic-topology ceiling for the `reshard` op (0 = only the
    /// hard [`crate::index::shard::MAX_SHARDS`] limit applies).
    shards_max: usize,
}

impl ServerState {
    /// The tracing plane, when `retrieval.trace` enabled it.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Count one admission-queue rejection. Mirrored into the
    /// scheduler's `rejected` stat when batching is on, so its
    /// historical meaning — "requests turned away by overload control" —
    /// keeps holding; the server-level counter is authoritative on both
    /// paths.
    fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        if let Some(sched) = &self.sched {
            sched.note_rejected();
        }
    }
}

/// The TCP request server: an event-driven reactor front end (Unix; see
/// [`Server::run`]) over a fixed worker pool and one shared [`Engine`].
pub struct Server {
    state: Arc<ServerState>,
    pool: WorkerPool,
    listener: TcpListener,
}

/// Default worker-pool size: one worker per available core, clamped to a
/// sensible serving range.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 16)
}

impl Server {
    /// Bind on `addr` (e.g. "127.0.0.1:7313") with the default pool size
    /// and no batching (library default).
    pub fn bind(addr: &str, engine: Engine, embedder: Embedder) -> Result<Server> {
        Self::bind_with_workers(addr, engine, embedder, default_workers())
    }

    /// Bind with an explicit worker-pool size; batching off.
    pub fn bind_with_workers(
        addr: &str,
        engine: Engine,
        embedder: Embedder,
        workers: usize,
    ) -> Result<Server> {
        let retrieval = RetrievalConfig {
            batching: false,
            max_inflight: 0, // historical behavior: unbounded queue
            // Historical behavior: no deadline shedding (a huge budget
            // overflows the stamp and disarms — see `bind_with_retrieval`).
            deadline_us: u64::MAX,
            ..RetrievalConfig::default()
        };
        Self::bind_with_retrieval(addr, engine, embedder, workers, &retrieval)
    }

    /// Bind with full serving knobs: worker count, bounded admission
    /// (`retrieval.max_inflight`) and the cross-query batch scheduler
    /// (`retrieval.batching`).
    pub fn bind_with_retrieval(
        addr: &str,
        engine: Engine,
        embedder: Embedder,
        workers: usize,
        retrieval: &RetrievalConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let engine = Arc::new(engine);
        let sched = retrieval
            .batching
            .then(|| BatchScheduler::new(engine.clone(), SchedConfig::from_retrieval(retrieval)));
        // Bounded admission: at most `max_inflight` requests queued
        // beyond the ones workers are executing (unbounded when 0).
        let workers = workers.max(1);
        let pool = match retrieval.max_inflight {
            0 => WorkerPool::new("edgerag-worker", workers),
            cap => WorkerPool::bounded("edgerag-worker", workers, cap),
        };
        let tracer = retrieval.trace.then(|| Tracer::new(retrieval.slow_query_us));
        let deadline_us = retrieval.resolved_deadline_us();
        Ok(Server {
            state: Arc::new(ServerState {
                engine,
                embedder,
                sched,
                tracer,
                running: AtomicBool::new(true),
                // A huge knob value (or µs overflow) disables shedding:
                // the stamp would never expire anyway.
                deadline: (deadline_us > 0)
                    .then(|| Duration::from_micros(deadline_us))
                    .filter(|d| Instant::now().checked_add(*d).is_some()),
                deadline_us,
                rejected: AtomicU64::new(0),
                deadline_shed: AtomicU64::new(0),
                shards_min: retrieval.shards_min.max(1),
                shards_max: retrieval.shards_max,
            }),
            pool,
            listener,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `shutdown` op (blocking). On Unix this runs the
    /// event-driven reactor front end; elsewhere it falls back to
    /// [`Server::run_threaded`]. Either way, connections and in-flight
    /// worker jobs are fully drained *before* the scheduler shuts down
    /// and the WAL checkpoints — no insert can race the consolidation.
    pub fn run(&self) -> Result<()> {
        #[cfg(unix)]
        reactor::run(&self.listener, &self.state, &self.pool.handle())?;
        #[cfg(not(unix))]
        self.accept_threaded()?;
        self.finish_shutdown();
        Ok(())
    }

    /// The pre-reactor thread-per-connection front end: one acceptor
    /// plus one handler thread per connection, each request parked on a
    /// blocking reply channel. Kept as the non-Unix fallback and as the
    /// baseline arm of the `connection_sweep` benchmark; the accept loop
    /// polls the running flag over a non-blocking listener (no
    /// self-connect wake) and drains handler threads before shutdown
    /// work starts.
    pub fn run_threaded(&self) -> Result<()> {
        self.accept_threaded()?;
        self.finish_shutdown();
        Ok(())
    }

    fn accept_threaded(&self) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let active = Arc::new(AtomicUsize::new(0));
        while self.state.running.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // Accepted sockets inherit non-blocking on some
                    // platforms; handlers want blocking-with-timeout.
                    let _ = stream.set_nonblocking(false);
                    let state = self.state.clone();
                    let pool = self.pool.handle();
                    let active_conns = active.clone();
                    active.fetch_add(1, Ordering::SeqCst);
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, &state, &pool);
                        active_conns.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        // Drain: handler threads notice the cleared running flag at
        // their next read timeout (≤200 ms) and exit; waiting them out
        // means no handler can submit work during shutdown.
        while active.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }

    /// Shutdown tail, run only after the front end has fully drained:
    /// close the scheduler stages (queued work completes, no new batches
    /// form), then consolidate the structural WAL into its snapshot so
    /// the next start replays one compact archive instead of a long
    /// tail. Best-effort — a flush failure just leaves the (recoverable)
    /// log as-is.
    fn finish_shutdown(&self) {
        if let Some(sched) = &self.state.sched {
            sched.shutdown();
        }
        if let Err(e) = self.state.engine.index().wal_checkpoint() {
            eprintln!("wal checkpoint on shutdown failed: {e:#}");
        }
    }
}

/// One thread-per-connection handler (the [`Server::run_threaded`]
/// path). Reads with a timeout over its own line buffer so an idle
/// keep-alive connection notices a cleared running flag within ~200 ms —
/// and, unlike `BufReader::read_line` under a socket timeout, never
/// loses a partially received line.
fn handle_connection(stream: TcpStream, state: &Arc<ServerState>, pool: &PoolHandle) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut out = stream.try_clone()?;
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let (response, shutdown) = match serve_request(trimmed, state, pool) {
                Ok(pair) => pair,
                Err(e) => (
                    Value::object(vec![("error", Value::str(format!("{e:#}")))]),
                    false,
                ),
            };
            writeln!(out, "{response}")?;
            if shutdown {
                // The non-blocking accept loop polls the flag — no
                // self-connect poke needed (or wanted: a failed connect
                // used to leave the server hung on accept).
                state.running.store(false, Ordering::SeqCst);
                return Ok(());
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if !state.running.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Render a protocol error as a one-line JSON response string.
pub(crate) fn error_line(e: &anyhow::Error) -> String {
    Value::object(vec![("error", Value::str(format!("{e:#}")))]).to_string()
}

/// Parse one request line and execute it. Returns the response plus
/// whether this request asked the server to shut down (decided on the
/// parsed `op`, never on raw request text).
fn serve_request(
    line: &str,
    state: &Arc<ServerState>,
    pool: &PoolHandle,
) -> Result<(Value, bool)> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    let op = req
        .req("op")?
        .as_str()
        .context("op must be a string")?
        .to_string();
    // Control ops answered inline — they must not queue behind work.
    if op == "ping" {
        return Ok((Value::object(vec![("ok", true.into())]), false));
    }
    if op == "shutdown" {
        return Ok((Value::object(vec![("ok", true.into())]), true));
    }
    // Admission instant: a traced request's span tree starts here (the
    // worker-queue wait shows up as its `admission` span), and the
    // query's deadline is stamped from it — front-end queue time counts
    // against the budget.
    let queued = Instant::now();
    let deadline = state.deadline.and_then(|d| queued.checked_add(d));
    // Everything else executes on the worker pool: N workers run N
    // requests concurrently against the shared engine (through the batch
    // scheduler when enabled). A full admission queue rejects the
    // request here — bounded backpressure instead of unbounded queueing.
    let (reply_tx, reply_rx) = mpsc::channel();
    let job_state = state.clone();
    let job = Box::new(move || {
        let _ = reply_tx.send(dispatch(&op, &req, &job_state, queued, deadline, false));
    });
    match pool.submit(job) {
        Ok(()) => {}
        Err(SubmitError::Full(_)) => {
            // Server-level overload stat (mirrored into the scheduler's
            // when batching is on): operators watching `{"op":"stats"}`
            // see the rejection on both paths.
            state.note_rejected();
            anyhow::bail!("server overloaded: admission queue full")
        }
        Err(SubmitError::Closed(_)) => anyhow::bail!("worker pool is shut down"),
    }
    let response = reply_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("worker dropped the request"))??;
    Ok((response, false))
}

/// Execute one op, bracketing `query`/`insert` with the tracing plane
/// when it is enabled: the worker thread carries the request's trace
/// from here through the scheduler, engine, index and WAL, and the
/// completed trace's id is stamped into the response. `from_reactor`
/// additionally records the front-end queue wait as a `reactor.wait`
/// span.
pub(crate) fn dispatch(
    op: &str,
    req: &Value,
    state: &ServerState,
    queued: Instant,
    deadline: Option<Instant>,
    from_reactor: bool,
) -> Result<Value> {
    let traced_op: Option<&'static str> = match op {
        "query" => Some("query"),
        "insert" => Some("insert"),
        _ => None,
    };
    match (traced_op, &state.tracer) {
        (Some(name), Some(tracer)) => {
            let guard = tracer.begin(name, queued);
            if from_reactor {
                // Reactor-parse to worker-pickup wait, as its own span
                // so operators can split front-end queueing from
                // execution.
                trace::record("reactor.wait", queued.elapsed().as_nanos() as u64, &[]);
            }
            let mut result = shed_or_dispatch(op, req, state, deadline);
            if let Some(trace) = guard.finish() {
                if let Ok(Value::Object(map)) = &mut result {
                    map.insert("trace_id".to_string(), trace.id.into());
                }
            }
            result
        }
        _ => shed_or_dispatch(op, req, state, deadline),
    }
}

/// Worker-dequeue shed gate: a query whose deadline expired while it
/// waited in the admission queue is answered with a distinct "deadline
/// exceeded" error instead of executed — under saturation the server
/// spends its workers on queries that can still be answered in time.
fn shed_or_dispatch(
    op: &str,
    req: &Value,
    state: &ServerState,
    deadline: Option<Instant>,
) -> Result<Value> {
    if op == "query" {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                state.deadline_shed.fetch_add(1, Ordering::Relaxed);
                trace::record_event("deadline.shed", &[]);
                anyhow::bail!(
                    "deadline exceeded: query spent its {}µs budget queued (shed before execution)",
                    state.deadline_us
                );
            }
        }
    }
    dispatch_op(op, req, state, deadline)
}

fn dispatch_op(
    op: &str,
    req: &Value,
    state: &ServerState,
    deadline: Option<Instant>,
) -> Result<Value> {
    match op {
        "query" => {
            let text = req.req("text")?.as_str().context("text")?;
            // Read-parallel; through the batch scheduler when enabled
            // (bit-identical results, fused kernel calls under load).
            // The admission deadline rides along so stage batches close
            // by it and expired riders shed at stage dequeue.
            let out = match &state.sched {
                Some(sched) => sched.handle_at(text, deadline)?,
                None => state.engine.handle(text)?,
            };
            let hits = Value::array(out.hits.iter().map(|&(id, score)| {
                Value::object(vec![
                    ("chunk", id.into()),
                    ("score", (score as f64).into()),
                ])
            }));
            Ok(Value::object(vec![
                ("hits", hits),
                ("retrieval_ms", out.retrieval.as_millis_f64().into()),
                ("ttft_ms", out.ttft.as_millis_f64().into()),
                (
                    "embed_gen_ms",
                    out.breakdown.get(Component::EmbedGen).as_millis_f64().into(),
                ),
                ("prompt_tokens", out.prompt_tokens.into()),
                ("cache_hits", out.events.cache_hits.into()),
                ("generated", out.events.generated.into()),
                ("loaded", out.events.loaded.into()),
                ("wall_us", (out.wall.as_micros() as u64).into()),
            ]))
        }
        "insert" => {
            let text = req.req("text")?.as_str().context("text")?;
            // Shard-scoped on an index with concurrent updates (only the
            // owning shard's write lease — queries to other shards keep
            // flowing), engine-exclusive otherwise.
            let (id, cluster) = state.engine.insert(text)?;
            Ok(Value::object(vec![
                ("id", id.into()),
                ("cluster", cluster.into()),
            ]))
        }
        "remove" => {
            // Chunk ids are u32; a silent truncation here used to map id
            // 2^32+5 onto id 5 and remove the wrong chunk.
            let raw = req.req("id")?.as_u64().context("id")?;
            let id = u32::try_from(raw).map_err(|_| {
                anyhow::anyhow!("id {raw} out of range: chunk ids are u32 (max {})", u32::MAX)
            })?;
            let removed = state.engine.remove(id)?;
            Ok(Value::object(vec![("removed", removed.into())]))
        }
        "stats" => {
            // Fully read-only: metrics snapshots + a shared index lease.
            // All index state comes through the VectorIndex accessors —
            // no concrete-type downcasts.
            let m = state.engine.metrics();
            let queries = m.queries();
            let retrieval = m.retrieval();
            let ttft = m.ttft();
            let (resident, hit_rate, threshold, shards, wal) = {
                let index = state.engine.index();
                (
                    index.resident_bytes(),
                    index.cache_stats().map(|s| s.hit_rate()).unwrap_or(0.0),
                    index.threshold_ms(),
                    index.shard_stats().map(shard_rows_json),
                    index.wal_stats(),
                )
            };
            let mut fields = vec![
                ("queries", queries.into()),
                ("retrieval_p50_ms", retrieval.percentile(50.0).as_millis_f64().into()),
                ("retrieval_p95_ms", retrieval.percentile(95.0).as_millis_f64().into()),
                ("ttft_p50_ms", ttft.percentile(50.0).as_millis_f64().into()),
                ("ttft_p95_ms", ttft.percentile(95.0).as_millis_f64().into()),
                ("resident_bytes", resident.into()),
                ("cache_hit_rate", hit_rate.into()),
                ("threshold_ms", threshold.into()),
                // Server-level overload/deadline stats: visible on both
                // the batched and unbatched paths.
                (
                    "server",
                    Value::object(vec![
                        ("rejected", state.rejected.load(Ordering::Relaxed).into()),
                        (
                            "deadline_shed",
                            state.deadline_shed.load(Ordering::Relaxed).into(),
                        ),
                        ("deadline_us", state.deadline_us.into()),
                    ]),
                ),
            ];
            if let Some(rows) = shards {
                fields.push(("shards", rows));
            }
            if let Some(w) = wal {
                fields.push((
                    "wal",
                    Value::object(vec![
                        ("frames_appended", w.frames_appended.into()),
                        ("rotations", w.rotations.into()),
                        ("bytes_on_disk", w.bytes_on_disk.into()),
                        ("replayed_ops", w.replayed_ops.into()),
                        ("append_us", (w.append_ns / 1_000).into()),
                        ("rotate_us", (w.rotate_ns / 1_000).into()),
                    ]),
                ));
            }
            if let Some(sched) = &state.sched {
                let s = sched.stats();
                fields.push((
                    "sched",
                    Value::object(vec![
                        ("submitted", s.submitted.into()),
                        ("bypassed", s.bypassed.into()),
                        ("rejected", s.rejected.into()),
                        ("embed", stage_json(&s.embed)),
                        ("probe", stage_json(&s.probe)),
                    ]),
                ));
            }
            Ok(Value::object(fields))
        }
        "shard-stats" => {
            // Just the per-shard load rows — what the rebalance planner
            // sees (and what the churn suite asserts against).
            let rows = state
                .engine
                .index()
                .shard_stats()
                .context("index is not sharded")?;
            Ok(Value::object(vec![("shards", shard_rows_json(rows))]))
        }
        "rebalance" => {
            // One explicit cross-shard rebalance round (the periodic
            // trigger is `rebalance_interval_ops`). Concurrent queries
            // keep serving bit-identical results while clusters move.
            let r = state.engine.rebalance()?;
            Ok(Value::object(vec![
                ("planned", r.planned.into()),
                ("migrated", r.migrated.into()),
                ("skipped", r.skipped.into()),
                ("spread_before", r.spread_before.into()),
                ("spread_after", r.spread_after.into()),
            ]))
        }
        "reshard" => {
            // Elastic topology: grow appends empty shards the planner
            // then fills; shrink drains-then-retires the tail shards.
            // Concurrent queries keep serving bit-identical results
            // through every topology swap. The target is clamped to the
            // serve bounds so an operator typo cannot collapse or
            // explode the topology.
            let raw = req.req("shards")?.as_u64().context("shards")? as usize;
            let ceiling = match state.shards_max {
                0 => crate::index::shard::MAX_SHARDS,
                max => max,
            };
            let target = raw.clamp(state.shards_min, ceiling.max(state.shards_min));
            let r = state.engine.reshard(target)?;
            Ok(Value::object(vec![
                ("requested", raw.into()),
                ("from", r.from.into()),
                ("to", r.to.into()),
                ("migrated", r.migrated.into()),
            ]))
        }
        "trace" => {
            let tracer = state
                .tracer
                .as_ref()
                .context("tracing is disabled (serve with --trace)")?;
            if let Some(id) = req.get("id") {
                let id = id.as_u64().context("id")?;
                let t = tracer
                    .find(id)
                    .with_context(|| format!("trace {id} not captured (rings wrapped?)"))?;
                return Ok(trace_json(&t));
            }
            Ok(Value::object(vec![
                ("slow_threshold_us", tracer.slow_threshold_us().into()),
                (
                    "recent",
                    Value::array(tracer.recent().iter().map(|t| trace_summary_json(t))),
                ),
                (
                    "slow",
                    Value::array(tracer.slow().iter().map(|t| trace_summary_json(t))),
                ),
            ]))
        }
        "metrics" => {
            // The whole metrics surface — query/TTFT histograms, modeled
            // component totals, event counters, per-shard rows, scheduler
            // stages, WAL activity, tracer counters — rendered in
            // Prometheus text exposition format. The line protocol wraps
            // the page in a one-field JSON object; an HTTP front-end (or
            // the CLI) unwraps `body` verbatim.
            Ok(Value::object(vec![(
                "body",
                Value::str(metrics_text(state)),
            )]))
        }
        other => anyhow::bail!("unknown op `{other}`"),
    }
}

/// One-line summary of a captured trace (the `trace` op's ring listing).
fn trace_summary_json(t: &QueryTrace) -> Value {
    Value::object(vec![
        ("id", t.id.into()),
        ("op", Value::str(t.op)),
        ("total_us", (t.total_ns / 1_000).into()),
        ("spans", t.spans.len().into()),
    ])
}

/// Full span tree of a captured trace. Spans carry offsets from the
/// admission instant so the tree renders on one time axis.
fn trace_json(t: &QueryTrace) -> Value {
    Value::object(vec![
        ("id", t.id.into()),
        ("op", Value::str(t.op)),
        ("total_us", (t.total_ns / 1_000).into()),
        ("dropped_spans", t.dropped_spans.into()),
        (
            "spans",
            Value::array(t.spans.iter().map(|s| {
                let tags = s.tags.iter().map(|&(k, v)| {
                    let v = match v {
                        TagValue::U64(n) => n.into(),
                        TagValue::Str(s) => Value::str(s),
                    };
                    (k, v)
                });
                Value::object(vec![
                    ("name", Value::str(s.name)),
                    ("start_us", (s.start_ns / 1_000).into()),
                    ("dur_us", (s.dur_ns / 1_000).into()),
                    ("tags", Value::object(tags.collect())),
                ])
            })),
        ),
    ])
}

/// Per-shard rows: where probes/inserts/migrations landed, each shard's
/// row-count load, threshold and cache state (shared by the `stats` and
/// `shard-stats` ops).
fn shard_rows_json(rows: Vec<crate::index::ShardStats>) -> Value {
    Value::array(rows.into_iter().map(|s| {
        Value::object(vec![
            ("shard", s.shard.into()),
            ("clusters", s.clusters.into()),
            ("rows", s.rows.into()),
            ("probes", s.probes.into()),
            ("cache_hits", s.cache_hits.into()),
            ("generated", s.generated.into()),
            ("loaded", s.loaded.into()),
            ("inserts", s.inserts.into()),
            ("removes", s.removes.into()),
            ("migrated_in", s.migrated_in.into()),
            ("migrated_out", s.migrated_out.into()),
            ("merges", s.merges.into()),
            (
                // Per-cluster probe heat (hottest first): the input a
                // future affinity-aware placement policy scores on.
                "hot_clusters",
                Value::array(s.hot_clusters.iter().map(|&(g, n)| {
                    Value::object(vec![("cluster", g.into()), ("probes", n.into())])
                })),
            ),
            ("threshold_ms", s.threshold_ms.into()),
            ("cache_used_bytes", s.cache_used_bytes.into()),
            (
                "cache",
                Value::object(vec![
                    ("hits", s.cache.hits.into()),
                    ("misses", s.cache.misses.into()),
                    ("insertions", s.cache.insertions.into()),
                    ("evictions", s.cache.evictions.into()),
                    (
                        "rejected_below_threshold",
                        s.cache.rejected_below_threshold.into(),
                    ),
                ]),
            ),
        ])
    }))
}

fn stage_json(s: &StageSnapshot) -> Value {
    Value::object(vec![
        ("submitted", s.submitted.into()),
        ("batches", s.batches.into()),
        ("occupancy", s.occupancy().into()),
        ("full_width", s.full_width.into()),
        ("window_expired", s.window_expired.into()),
        ("shed", s.shed.into()),
    ])
}

/// One Prometheus histogram family from a [`LatencySeries`]: the
/// occupied log-spaced bins as cumulative `_bucket` lines (upper bounds
/// in seconds), plus the mandatory `+Inf` bucket, `_sum` and `_count`.
fn write_histogram(out: &mut String, name: &str, help: &str, series: &LatencySeries) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (upper_ns, cumulative) in series.prom_buckets() {
        let le = upper_ns as f64 / 1e9;
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cumulative}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", series.len());
    let _ = writeln!(out, "{name}_sum {}", series.sum_nanos() as f64 / 1e9);
    let _ = writeln!(out, "{name}_count {}", series.len());
}

/// Render the whole metrics surface in Prometheus text exposition
/// format: latency histograms, modeled per-component time, event
/// counters, index/cache gauges, per-shard rows, scheduler stages, WAL
/// activity and tracer counters. Read-only — snapshots plus one shared
/// index lease, same as the `stats` op.
fn metrics_text(state: &ServerState) -> String {
    let mut out = String::new();
    let m = state.engine.metrics();

    let _ = writeln!(out, "# HELP edgerag_queries_total Queries served.");
    let _ = writeln!(out, "# TYPE edgerag_queries_total counter");
    let _ = writeln!(out, "edgerag_queries_total {}", m.queries());

    // Server-level overload/deadline counters (both serving paths).
    let _ = writeln!(
        out,
        "# HELP edgerag_server_rejected_total Requests refused because the admission queue was full."
    );
    let _ = writeln!(out, "# TYPE edgerag_server_rejected_total counter");
    let _ = writeln!(
        out,
        "edgerag_server_rejected_total {}",
        state.rejected.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP edgerag_server_deadline_shed_total Queries shed at worker dequeue after their deadline expired."
    );
    let _ = writeln!(out, "# TYPE edgerag_server_deadline_shed_total counter");
    let _ = writeln!(
        out,
        "edgerag_server_deadline_shed_total {}",
        state.deadline_shed.load(Ordering::Relaxed)
    );

    write_histogram(
        &mut out,
        "edgerag_retrieval_latency_seconds",
        "End-to-end retrieval latency.",
        &m.retrieval(),
    );
    write_histogram(
        &mut out,
        "edgerag_ttft_latency_seconds",
        "Time to first token (retrieval + prefill).",
        &m.ttft(),
    );

    let _ = writeln!(
        out,
        "# HELP edgerag_component_seconds_total Modeled time per pipeline component."
    );
    let _ = writeln!(out, "# TYPE edgerag_component_seconds_total counter");
    for c in Component::ALL {
        let _ = writeln!(
            out,
            "edgerag_component_seconds_total{{component=\"{}\"}} {}",
            c.name(),
            m.component_total(c).as_secs_f64()
        );
    }

    let counters = m.counters_snapshot();
    if !counters.is_empty() {
        let _ = writeln!(out, "# HELP edgerag_events_total Named event counters.");
        let _ = writeln!(out, "# TYPE edgerag_events_total counter");
        for (name, n) in counters {
            let _ = writeln!(out, "edgerag_events_total{{event=\"{name}\"}} {n}");
        }
    }

    // One shared index lease for everything the index exposes.
    {
        let index = state.engine.index();
        let _ = writeln!(
            out,
            "# HELP edgerag_index_resident_bytes Bytes of index state resident in memory."
        );
        let _ = writeln!(out, "# TYPE edgerag_index_resident_bytes gauge");
        let _ = writeln!(out, "edgerag_index_resident_bytes {}", index.resident_bytes());
        let _ = writeln!(
            out,
            "# HELP edgerag_cache_used_bytes Embedding-cache bytes in use."
        );
        let _ = writeln!(out, "# TYPE edgerag_cache_used_bytes gauge");
        let _ = writeln!(out, "edgerag_cache_used_bytes {}", index.cache_used_bytes());
        let _ = writeln!(
            out,
            "# HELP edgerag_stored_clusters Cluster embeddings spilled to disk."
        );
        let _ = writeln!(out, "# TYPE edgerag_stored_clusters gauge");
        let _ = writeln!(out, "edgerag_stored_clusters {}", index.stored_clusters());
        let _ = writeln!(out, "# HELP edgerag_stored_bytes Bytes spilled to disk.");
        let _ = writeln!(out, "# TYPE edgerag_stored_bytes gauge");
        let _ = writeln!(out, "edgerag_stored_bytes {}", index.stored_bytes());
        let _ = writeln!(
            out,
            "# HELP edgerag_cache_admission_threshold_seconds Cost-aware cache admission threshold."
        );
        let _ = writeln!(out, "# TYPE edgerag_cache_admission_threshold_seconds gauge");
        let _ = writeln!(
            out,
            "edgerag_cache_admission_threshold_seconds {}",
            index.threshold_ms() / 1e3
        );
        let _ = writeln!(
            out,
            "# HELP edgerag_probe_rebuilds_total Lock-free probe-table snapshot rebuilds."
        );
        let _ = writeln!(out, "# TYPE edgerag_probe_rebuilds_total counter");
        let _ = writeln!(out, "edgerag_probe_rebuilds_total {}", index.probe_rebuilds());

        if let Some(c) = index.cache_stats() {
            let _ = writeln!(
                out,
                "# HELP edgerag_cache_ops_total Embedding-cache operations by outcome."
            );
            let _ = writeln!(out, "# TYPE edgerag_cache_ops_total counter");
            for (op, n) in [
                ("hit", c.hits),
                ("miss", c.misses),
                ("insertion", c.insertions),
                ("eviction", c.evictions),
                ("rejected_below_threshold", c.rejected_below_threshold),
            ] {
                let _ = writeln!(out, "edgerag_cache_ops_total{{op=\"{op}\"}} {n}");
            }
        }

        if let Some(rows) = index.shard_stats() {
            let _ = writeln!(out, "# HELP edgerag_shard_rows Vector rows per shard.");
            let _ = writeln!(out, "# TYPE edgerag_shard_rows gauge");
            for s in &rows {
                let _ = writeln!(out, "edgerag_shard_rows{{shard=\"{}\"}} {}", s.shard, s.rows);
            }
            let _ = writeln!(out, "# HELP edgerag_shard_clusters Clusters per shard.");
            let _ = writeln!(out, "# TYPE edgerag_shard_clusters gauge");
            for s in &rows {
                let _ = writeln!(
                    out,
                    "edgerag_shard_clusters{{shard=\"{}\"}} {}",
                    s.shard, s.clusters
                );
            }
            let _ = writeln!(
                out,
                "# HELP edgerag_shard_ops_total Per-shard operation counters."
            );
            let _ = writeln!(out, "# TYPE edgerag_shard_ops_total counter");
            for s in &rows {
                for (op, n) in [
                    ("probes", s.probes),
                    ("cache_hits", s.cache_hits),
                    ("generated", s.generated),
                    ("loaded", s.loaded),
                    ("inserts", s.inserts),
                    ("removes", s.removes),
                    ("migrated_in", s.migrated_in),
                    ("migrated_out", s.migrated_out),
                    ("merges", s.merges),
                ] {
                    let _ = writeln!(
                        out,
                        "edgerag_shard_ops_total{{shard=\"{}\",op=\"{op}\"}} {n}",
                        s.shard
                    );
                }
            }
        }

        if let Some(w) = index.wal_stats() {
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_frames_appended_total Structural WAL frames appended."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_frames_appended_total counter");
            let _ = writeln!(out, "edgerag_wal_frames_appended_total {}", w.frames_appended);
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_rotations_total Snapshot-consolidation rotations."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_rotations_total counter");
            let _ = writeln!(out, "edgerag_wal_rotations_total {}", w.rotations);
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_bytes_on_disk Snapshot + live log bytes on disk."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_bytes_on_disk gauge");
            let _ = writeln!(out, "edgerag_wal_bytes_on_disk {}", w.bytes_on_disk);
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_replayed_ops_total Operations replayed at startup recovery."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_replayed_ops_total counter");
            let _ = writeln!(out, "edgerag_wal_replayed_ops_total {}", w.replayed_ops);
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_append_seconds_total Wall time spent appending WAL frames."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_append_seconds_total counter");
            let _ = writeln!(
                out,
                "edgerag_wal_append_seconds_total {}",
                w.append_ns as f64 / 1e9
            );
            let _ = writeln!(
                out,
                "# HELP edgerag_wal_rotate_seconds_total Wall time spent rotating the WAL."
            );
            let _ = writeln!(out, "# TYPE edgerag_wal_rotate_seconds_total counter");
            let _ = writeln!(
                out,
                "edgerag_wal_rotate_seconds_total {}",
                w.rotate_ns as f64 / 1e9
            );
        }
    }

    if let Some(sched) = &state.sched {
        let s = sched.stats();
        let _ = writeln!(
            out,
            "# HELP edgerag_sched_requests_total Scheduler admissions by outcome."
        );
        let _ = writeln!(out, "# TYPE edgerag_sched_requests_total counter");
        for (outcome, n) in [
            ("submitted", s.submitted),
            ("bypassed", s.bypassed),
            ("rejected", s.rejected),
        ] {
            let _ = writeln!(out, "edgerag_sched_requests_total{{outcome=\"{outcome}\"}} {n}");
        }
        let _ = writeln!(
            out,
            "# HELP edgerag_stage_ops_total Per-stage batcher counters."
        );
        let _ = writeln!(out, "# TYPE edgerag_stage_ops_total counter");
        let _ = writeln!(out, "# HELP edgerag_stage_occupancy Mean items per fused batch.");
        let _ = writeln!(out, "# TYPE edgerag_stage_occupancy gauge");
        for (stage, snap) in [("embed", &s.embed), ("probe", &s.probe)] {
            for (op, n) in [
                ("submitted", snap.submitted),
                ("batches", snap.batches),
                ("full_width", snap.full_width),
                ("window_expired", snap.window_expired),
                ("shed", snap.shed),
            ] {
                let _ = writeln!(
                    out,
                    "edgerag_stage_ops_total{{stage=\"{stage}\",op=\"{op}\"}} {n}"
                );
            }
            let _ = writeln!(
                out,
                "edgerag_stage_occupancy{{stage=\"{stage}\"}} {}",
                snap.occupancy()
            );
        }
    }

    if let Some(tracer) = &state.tracer {
        let t = tracer.stats();
        let _ = writeln!(out, "# HELP edgerag_traces_total Query traces by state.");
        let _ = writeln!(out, "# TYPE edgerag_traces_total counter");
        for (trace_state, n) in [
            ("started", t.started),
            ("finished", t.finished),
            ("slow", t.slow),
        ] {
            let _ = writeln!(out, "edgerag_traces_total{{state=\"{trace_state}\"}} {n}");
        }
        let _ = writeln!(
            out,
            "# HELP edgerag_trace_slow_threshold_seconds Slow-query capture threshold."
        );
        let _ = writeln!(out, "# TYPE edgerag_trace_slow_threshold_seconds gauge");
        let _ = writeln!(
            out,
            "edgerag_trace_slow_threshold_seconds {}",
            tracer.slow_threshold_us() as f64 / 1e6
        );
    }

    out
}

/// Minimal blocking client for the line-JSON protocol (used by the CLI and
/// tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a serving endpoint (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send one request object and read its one-line response.
    pub fn call(&mut self, request: &Value) -> Result<Value> {
        writeln!(self.writer, "{request}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Convenience wrapper for the `query` op.
    pub fn query(&mut self, text: &str) -> Result<Value> {
        self.call(&Value::object(vec![
            ("op", Value::str("query")),
            ("text", Value::str(text)),
        ]))
    }
}
