//! Request server: a line-delimited JSON protocol over TCP.
//!
//! The crate cache has no async runtime, so the server is thread-based:
//! one acceptor + one handler thread per connection, all funneling into
//! the single-threaded serving pipeline (edge devices serve one query at a
//! time; the interesting concurrency — compute — lives on the PJRT
//! executor thread).
//!
//! Protocol (one JSON object per line):
//!   {"op":"query","text":"..."}      → hits + latency breakdown
//!   {"op":"insert","text":"..."}     → {"id": N, "cluster": C}
//!   {"op":"remove","id":N}           → {"removed": bool}
//!   {"op":"stats"}                   → serving metrics
//!   {"op":"ping"}                    → {"ok": true}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{RagPipeline, TextStore};
use crate::embedding::Embedder;
use crate::index::EdgeIndex;
use crate::json::{self, Value};
use crate::simtime::Component;

/// Shared server state.
pub struct ServerState {
    pub pipeline: Mutex<RagPipeline>,
    pub embedder: Embedder,
    /// Shared with the pipeline: inserted chunks' text goes here so prompt
    /// assembly can fetch it (ids are allocated by the store).
    texts: TextStore,
    running: AtomicBool,
}

pub struct Server {
    state: Arc<ServerState>,
    listener: TcpListener,
}

impl Server {
    /// Bind on `addr` (e.g. "127.0.0.1:7313").
    pub fn bind(addr: &str, pipeline: RagPipeline, embedder: Embedder) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let texts = pipeline.texts();
        Ok(Server {
            state: Arc::new(ServerState {
                pipeline: Mutex::new(pipeline),
                embedder,
                texts,
                running: AtomicBool::new(true),
            }),
            listener,
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `shutdown` op (blocking).
    pub fn run(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if !self.state.running.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let state = self.state.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &state);
            });
        }
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match dispatch(trimmed, state) {
            Ok(v) => v,
            Err(e) => Value::object(vec![("error", Value::str(format!("{e:#}")))]),
        };
        writeln!(out, "{response}")?;
        if trimmed.contains("\"shutdown\"") {
            state.running.store(false, Ordering::SeqCst);
            // poke the acceptor loop awake
            let _ = TcpStream::connect(out.local_addr()?);
            return Ok(());
        }
    }
}

fn dispatch(line: &str, state: &ServerState) -> Result<Value> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    let op = req.req("op")?.as_str().context("op must be a string")?;
    match op {
        "ping" => Ok(Value::object(vec![("ok", true.into())])),
        "shutdown" => Ok(Value::object(vec![("ok", true.into())])),
        "query" => {
            let text = req.req("text")?.as_str().context("text")?;
            let mut p = state.pipeline.lock().unwrap();
            let out = p.handle(text)?;
            let hits = Value::array(out.hits.iter().map(|&(id, score)| {
                Value::object(vec![
                    ("chunk", id.into()),
                    ("score", (score as f64).into()),
                ])
            }));
            Ok(Value::object(vec![
                ("hits", hits),
                ("retrieval_ms", out.retrieval.as_millis_f64().into()),
                ("ttft_ms", out.ttft.as_millis_f64().into()),
                (
                    "embed_gen_ms",
                    out.breakdown.get(Component::EmbedGen).as_millis_f64().into(),
                ),
                ("prompt_tokens", out.prompt_tokens.into()),
                ("cache_hits", out.events.cache_hits.into()),
                ("generated", out.events.generated.into()),
                ("loaded", out.events.loaded.into()),
                ("wall_us", (out.wall.as_micros() as u64).into()),
            ]))
        }
        "insert" => {
            let text = req.req("text")?.as_str().context("text")?;
            let emb = state.embedder.embed_one(text)?;
            let mut p = state.pipeline.lock().unwrap();
            // Allocate the id from the shared text store while holding the
            // pipeline lock, so ids and index state stay consistent.
            let id = state.texts.push(text.to_string());
            let edge = p
                .index_mut()
                .as_any_mut()
                .downcast_mut::<EdgeIndex>()
                .context("insert requires an EdgeRAG index")?;
            let cluster = edge.insert_chunk(id, text, &emb)?;
            Ok(Value::object(vec![
                ("id", id.into()),
                ("cluster", cluster.into()),
            ]))
        }
        "remove" => {
            let id = req.req("id")?.as_u64().context("id")? as u32;
            let mut p = state.pipeline.lock().unwrap();
            let edge = p
                .index_mut()
                .as_any_mut()
                .downcast_mut::<EdgeIndex>()
                .context("remove requires an EdgeRAG index")?;
            let removed = edge.remove_chunk(id)?;
            Ok(Value::object(vec![("removed", removed.into())]))
        }
        "stats" => {
            let mut p = state.pipeline.lock().unwrap();
            let queries = p.metrics().queries();
            let resident = p.index().resident_bytes();
            let (hit_rate, threshold) = match p
                .index_mut()
                .as_any_mut()
                .downcast_mut::<EdgeIndex>()
            {
                Some(e) => (
                    e.cache_stats().map(|s| s.hit_rate()).unwrap_or(0.0),
                    e.threshold_ms(),
                ),
                None => (0.0, 0.0),
            };
            let m = p.metrics_mut();
            Ok(Value::object(vec![
                ("queries", queries.into()),
                ("retrieval_p50_ms", m.retrieval.percentile(50.0).as_millis_f64().into()),
                ("retrieval_p95_ms", m.retrieval.percentile(95.0).as_millis_f64().into()),
                ("ttft_p50_ms", m.ttft.percentile(50.0).as_millis_f64().into()),
                ("ttft_p95_ms", m.ttft.percentile(95.0).as_millis_f64().into()),
                ("resident_bytes", resident.into()),
                ("cache_hit_rate", hit_rate.into()),
                ("threshold_ms", threshold.into()),
            ]))
        }
        other => anyhow::bail!("unknown op `{other}`"),
    }
}

/// Minimal blocking client for the line-JSON protocol (used by the CLI and
/// tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, request: &Value) -> Result<Value> {
        writeln!(self.writer, "{request}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    pub fn query(&mut self, text: &str) -> Result<Value> {
        self.call(&Value::object(vec![
            ("op", Value::str("query")),
            ("text", Value::str(text)),
        ]))
    }
}
