//! The event-driven serving front end: every connection multiplexed
//! onto one reactor thread through a non-blocking `poll(2)` readiness
//! loop.
//!
//! ## Why not thread-per-connection
//!
//! The PR 1-era front end parked one handler thread per connection plus
//! one blocking reply channel per request, so *connection count* — not
//! the engine — capped concurrency, and a thousand idle keep-alive
//! clients cost a thousand stacks. Here an idle connection costs one
//! slab slot and two byte buffers; the only threads in the system are
//! the reactor itself, the fixed worker pool, and the two batch-stage
//! threads.
//!
//! ## Per-connection state machine
//!
//! ```text
//!            POLLIN                 complete line
//!   readable ──────► read buffer ───────────────► parse ──► control op
//!                        │ (cap: MAX_LINE_BYTES)    │        (inline
//!                        │                          ▼         reply)
//!                        │                  submit to bounded
//!                        │                  admission queue ──► rejected?
//!                        │                          │           (inline
//!                        │                          ▼            error)
//!                        │                  pending (seq-ordered;
//!                        │                  cap: MAX_PIPELINE)
//!                        │                          │ completion queue
//!                        ▼                          ▼   + wake pipe
//!                     paused when saturated   write buffer ──► POLLOUT
//! ```
//!
//! Responses append to the write buffer strictly in request order
//! (`next_seq`/`next_flush` plus a parking map for out-of-order worker
//! completions), so pipelined clients read answers in the order they
//! asked.
//!
//! ## Wake path
//!
//! Workers finish a request by pushing `(token, generation, seq,
//! response)` onto the shared completion queue and writing one byte to
//! the **wake pipe**; the reactor polls the pipe's read end alongside
//! the sockets, drains the queue, and routes each completion to its
//! (generation-checked) connection. Shutdown needs no self-connect
//! poke: the `shutdown` op is handled inline on the reactor thread,
//! which stops accepting, stops reading, drains every in-flight worker
//! job and write buffer, and returns — the caller then closes scheduler
//! stages and checkpoints the WAL with the whole pipeline provably
//! quiescent.
//!
//! ## No new dependencies
//!
//! `poll(2)`/`pipe(2)`/`fcntl(2)` are reached through direct `extern
//! "C"` declarations — std already links libc on every Unix target, so
//! this adds syscalls, not crates.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::json::{self, Value};
use crate::pool::{PoolHandle, SubmitError};

use super::{dispatch, error_line, ServerState};

/// Largest accepted request line; a connection that exceeds it without
/// a newline gets an error response and is closed after the flush.
const MAX_LINE_BYTES: usize = 1 << 20;
/// In-flight + parked responses allowed per connection before the
/// reactor stops reading from it (read resumes as completions land).
const MAX_PIPELINE: usize = 64;
/// Unflushed response bytes tolerated per connection; a client that
/// pipelines requests but never reads responses is disconnected rather
/// than buffered without bound.
const MAX_WRITE_BUFFER: usize = 4 << 20;
/// Read syscalls per connection per readiness round — bounds how long
/// one streaming client can monopolize the loop (poll is
/// level-triggered; leftover bytes surface next round).
const MAX_READS_PER_ROUND: usize = 16;
/// Safety tick so the loop re-checks drain conditions even with no
/// socket or wake activity.
const POLL_TIMEOUT_MS: i32 = 500;
/// How long a draining server waits for clients to read their final
/// responses before force-closing the sockets (worker jobs are still
/// awaited — only unread output is abandoned).
const DRAIN_GRACE: Duration = Duration::from_secs(5);

// --------------------------------------------------------------------------
// poll(2) / pipe(2) FFI (std links libc on every Unix target)
// --------------------------------------------------------------------------

#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
type NfdsT = u64;
#[cfg(not(target_os = "linux"))]
type NfdsT = u32;

const F_GETFL: i32 = 3;
const F_SETFL: i32 = 4;
#[cfg(target_os = "linux")]
const O_NONBLOCK: i32 = 0o4000;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: i32 = 0x4;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    fn pipe(fds: *mut i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
}

/// The reactor's wake pipe: workers write one byte after pushing a
/// completion; the poll loop reads the pipe level-triggered and drains
/// it. Both ends non-blocking — a full pipe is fine (the queue being
/// non-empty guarantees an unconsumed wake byte already exists).
struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakePipe {
    fn new() -> Result<WakePipe> {
        let mut fds = [0i32; 2];
        let rc = unsafe { pipe(fds.as_mut_ptr()) };
        anyhow::ensure!(
            rc == 0,
            "pipe(2) failed: {}",
            std::io::Error::last_os_error()
        );
        for fd in fds {
            unsafe {
                let flags = fcntl(fd, F_GETFL, 0);
                fcntl(fd, F_SETFL, flags | O_NONBLOCK);
            }
        }
        Ok(WakePipe {
            read_fd: fds[0],
            write_fd: fds[1],
        })
    }

    fn wake(&self) {
        let b = [1u8];
        // EAGAIN (pipe full of wakes) is fine — see the struct docs.
        unsafe { write(self.write_fd, b.as_ptr(), 1) };
    }

    fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n < buf.len() as isize {
                break; // short read or EAGAIN: pipe is empty
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

// --------------------------------------------------------------------------
// Completion queue
// --------------------------------------------------------------------------

/// One finished worker job: the rendered response line routed back to
/// connection `token` (generation-checked against slot reuse).
struct Completion {
    token: usize,
    generation: u64,
    seq: u64,
    line: String,
}

/// The worker→reactor channel: a mutexed vector plus the wake pipe.
/// Jobs hold an `Arc` to it, so the pipe outlives the reactor if
/// stragglers are still finishing.
struct Completions {
    queue: Mutex<Vec<Completion>>,
    pipe: WakePipe,
    /// Jobs submitted whose completion the reactor has not yet taken —
    /// every job pushes a completion even on panic, so this draining to
    /// zero proves the worker pool is quiescent for this server.
    outstanding: AtomicU64,
}

impl Completions {
    fn new() -> Result<Completions> {
        Ok(Completions {
            queue: Mutex::new(Vec::new()),
            pipe: WakePipe::new()?,
            outstanding: AtomicU64::new(0),
        })
    }

    fn push(&self, c: Completion) {
        self.queue.lock().unwrap().push(c);
        self.pipe.wake();
    }

    fn take(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.queue.lock().unwrap())
    }

    fn note_submitted(&self) {
        self.outstanding.fetch_add(1, Ordering::SeqCst);
    }

    fn note_taken(&self, n: u64) {
        self.outstanding.fetch_sub(n, Ordering::SeqCst);
    }

    fn outstanding(&self) -> u64 {
        self.outstanding.load(Ordering::SeqCst)
    }
}

// --------------------------------------------------------------------------
// Per-connection state machine
// --------------------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    /// Guards completions against slab-slot reuse.
    generation: u64,
    /// Bytes received but not yet parsed into lines.
    rbuf: Vec<u8>,
    /// Bytes queued to send; `wpos` is how far they are flushed.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Next request sequence on this connection (every parsed line gets
    /// one, inline or submitted).
    next_seq: u64,
    /// The sequence the write buffer ends at: responses append strictly
    /// in request order.
    next_flush: u64,
    /// Out-of-order completions parked until their turn.
    parked: HashMap<u64, String>,
    /// Requests submitted to the pool whose completion hasn't landed.
    inflight: usize,
    /// Peer EOF (or fatal read error): parse no further requests; close
    /// once pending responses flush.
    read_closed: bool,
    /// Close as soon as the write buffer drains and nothing is pending
    /// (oversized line, write-side overflow).
    close_after_flush: bool,
    /// Hard failure (write error, POLLERR): discard immediately.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, generation: u64) -> Conn {
        Conn {
            stream,
            generation,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            next_seq: 0,
            next_flush: 0,
            parked: HashMap::new(),
            inflight: 0,
            read_closed: false,
            close_after_flush: false,
            dead: false,
        }
    }

    /// Non-blocking read into the line buffer (bounded per round).
    fn fill_rbuf(&mut self) {
        let mut chunk = [0u8; 4096];
        for _ in 0..MAX_READS_PER_ROUND {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.read_closed = true;
                    self.dead = true;
                    break;
                }
            }
        }
    }

    /// Deliver one response (inline or worker completion): append to the
    /// write buffer in sequence order, parking it if earlier responses
    /// are still pending.
    fn complete(&mut self, seq: u64, line: String) {
        self.parked.insert(seq, line);
        while let Some(next) = self.parked.remove(&self.next_flush) {
            self.wbuf.extend_from_slice(next.as_bytes());
            self.wbuf.push(b'\n');
            self.next_flush += 1;
        }
        if self.wbuf.len() - self.wpos > MAX_WRITE_BUFFER {
            // Slow consumer: pipelining without reading responses.
            self.dead = true;
        }
    }

    /// Flush as much of the write buffer as the socket accepts.
    fn flush(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
    }

    fn flushed(&self) -> bool {
        self.wpos >= self.wbuf.len()
    }

    /// Nothing submitted, parked or buffered for this connection.
    fn quiescent(&self) -> bool {
        self.inflight == 0 && self.parked.is_empty() && self.flushed()
    }
}

/// What parsing one request line asked of the server.
#[derive(PartialEq, Eq)]
enum LineOutcome {
    Continue,
    Shutdown,
}

// --------------------------------------------------------------------------
// The reactor loop
// --------------------------------------------------------------------------

/// Run the readiness loop until a `shutdown` op has been served **and**
/// every accepted connection and in-flight worker job has drained. The
/// caller (`Server::run`) performs scheduler shutdown and the WAL
/// checkpoint after this returns — at that point nothing can be
/// mutating the engine on the server's behalf.
pub(super) fn run(
    listener: &TcpListener,
    state: &Arc<ServerState>,
    pool: &PoolHandle,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("nonblocking listener")?;
    let comps = Arc::new(Completions::new()?);
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut generation: u64 = 0;
    let mut draining = !state.running.load(Ordering::SeqCst);
    let mut drain_started: Option<Instant> = None;

    let mut pollfds: Vec<PollFd> = Vec::new();
    // pollfds[i] for i >= fixed belongs to connection tokens[i - fixed].
    let mut tokens: Vec<usize> = Vec::new();

    loop {
        // --- Build the poll set: wake pipe, listener, ready conns.
        pollfds.clear();
        tokens.clear();
        pollfds.push(PollFd {
            fd: comps.pipe.read_fd,
            events: POLLIN,
            revents: 0,
        });
        let listening = !draining;
        if listening {
            pollfds.push(PollFd {
                fd: listener.as_raw_fd(),
                events: POLLIN,
                revents: 0,
            });
        }
        let fixed = pollfds.len();
        for (token, slot) in conns.iter().enumerate() {
            let Some(c) = slot else { continue };
            let mut events: i16 = 0;
            let saturated = c.inflight + c.parked.len() >= MAX_PIPELINE;
            if !draining && !c.read_closed && !c.close_after_flush && !saturated {
                events |= POLLIN;
            }
            if !c.flushed() {
                events |= POLLOUT;
            }
            if events != 0 {
                pollfds.push(PollFd {
                    fd: c.stream.as_raw_fd(),
                    events,
                    revents: 0,
                });
                tokens.push(token);
            }
            // A conn with no events still progresses via completions.
        }

        let rc = unsafe { poll(pollfds.as_mut_ptr(), pollfds.len() as NfdsT, POLL_TIMEOUT_MS) };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == ErrorKind::Interrupted {
                continue;
            }
            return Err(err).context("poll(2)");
        }

        // --- Wake pipe: clear the level-triggered bytes.
        if pollfds[0].revents != 0 {
            comps.pipe.drain();
        }

        // --- Route finished worker jobs to their connections.
        let finished = comps.take();
        if !finished.is_empty() {
            comps.note_taken(finished.len() as u64);
            for done in finished {
                let Some(slot) = conns.get_mut(done.token) else {
                    continue;
                };
                let Some(c) = slot.as_mut() else {
                    continue; // connection force-closed while the job ran
                };
                if c.generation != done.generation {
                    continue; // slot reused: stale completion
                }
                c.inflight = c.inflight.saturating_sub(1);
                c.complete(done.seq, done.line);
            }
        }

        // --- Accept new connections.
        if listening && pollfds[1].revents != 0 {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        generation += 1;
                        let conn = Conn::new(stream, generation);
                        match free.pop() {
                            Some(token) => conns[token] = Some(conn),
                            None => conns.push(Some(conn)),
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        }

        // --- Socket readiness: reads and error states. (Writes happen
        // in the sweep below so completion-driven output needs no extra
        // poll round.)
        for (i, pfd) in pollfds.iter().enumerate().skip(fixed) {
            if pfd.revents == 0 {
                continue;
            }
            let token = tokens[i - fixed];
            let Some(c) = conns[token].as_mut() else {
                continue;
            };
            if pfd.revents & (POLLERR | POLLNVAL) != 0 {
                c.dead = true;
                continue;
            }
            if pfd.revents & (POLLIN | POLLHUP) != 0 {
                c.fill_rbuf();
            }
        }

        // --- Per-connection sweep: parse, flush, reap.
        let mut shutdown_requested = false;
        for (token, slot) in conns.iter_mut().enumerate() {
            let Some(c) = slot.as_mut() else { continue };
            if !c.dead
                && !draining
                && parse_lines(c, token, state, pool, &comps) == LineOutcome::Shutdown
            {
                shutdown_requested = true;
            }
            if !c.dead {
                c.flush();
            }
            let finished = if draining {
                c.quiescent()
            } else {
                c.quiescent() && (c.read_closed || c.close_after_flush)
            };
            if c.dead || (finished && c.inflight == 0) {
                // Dropping the Conn closes the socket; inflight jobs of
                // a dead conn finish into a generation mismatch.
                *slot = None;
                free.push(token);
            }
        }
        if shutdown_requested {
            state.running.store(false, Ordering::SeqCst);
            draining = true;
            drain_started = Some(Instant::now());
        }

        // --- Drain: exit once every connection is gone and every
        // submitted job's completion has been taken.
        if draining {
            if let Some(since) = drain_started {
                if since.elapsed() > DRAIN_GRACE {
                    // Clients that never read their final responses:
                    // abandon the unread output, keep awaiting jobs.
                    for (token, slot) in conns.iter_mut().enumerate() {
                        if slot.is_some() {
                            *slot = None;
                            free.push(token);
                        }
                    }
                }
            }
            if comps.outstanding() == 0 && conns.iter().all(|slot| slot.is_none()) {
                return Ok(());
            }
        }
    }
}

/// Parse complete lines out of `c.rbuf` and start each request:
/// control ops answer inline; everything else is submitted to the
/// worker pool with this connection's routing coordinates. Stops at the
/// pipeline cap (reads stay paused until completions land).
fn parse_lines(
    c: &mut Conn,
    token: usize,
    state: &Arc<ServerState>,
    pool: &PoolHandle,
    comps: &Arc<Completions>,
) -> LineOutcome {
    loop {
        if c.inflight + c.parked.len() >= MAX_PIPELINE {
            return LineOutcome::Continue;
        }
        let Some(pos) = c.rbuf.iter().position(|&b| b == b'\n') else {
            if c.rbuf.len() > MAX_LINE_BYTES {
                let seq = c.next_seq;
                c.next_seq += 1;
                c.complete(
                    seq,
                    error_line(&anyhow::anyhow!(
                        "request line exceeds {MAX_LINE_BYTES} bytes"
                    )),
                );
                c.rbuf.clear();
                c.read_closed = true;
                c.close_after_flush = true;
            }
            return LineOutcome::Continue;
        };
        let raw: Vec<u8> = c.rbuf.drain(..=pos).collect();
        let line = String::from_utf8_lossy(&raw);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if handle_line(trimmed, c, token, state, pool, comps) == LineOutcome::Shutdown {
            // Stop parsing: requests pipelined after shutdown are
            // dropped (the drain answers what was already submitted).
            return LineOutcome::Shutdown;
        }
    }
}

/// Start one request: allocate its response sequence, answer control
/// ops and parse failures inline, and hand real work to the pool with a
/// completion-pushing job wrapper.
fn handle_line(
    line: &str,
    c: &mut Conn,
    token: usize,
    state: &Arc<ServerState>,
    pool: &PoolHandle,
    comps: &Arc<Completions>,
) -> LineOutcome {
    let seq = c.next_seq;
    c.next_seq += 1;
    // Parse on the reactor thread (cheap); execute on the pool.
    let parsed: Result<(String, Value)> = json::parse(line)
        .map_err(|e| anyhow::anyhow!("bad request: {e}"))
        .and_then(|req| {
            let op = req
                .req("op")?
                .as_str()
                .context("op must be a string")?
                .to_string();
            Ok((op, req))
        });
    let (op, req) = match parsed {
        Ok(pair) => pair,
        Err(e) => {
            c.complete(seq, error_line(&e));
            return LineOutcome::Continue;
        }
    };
    // Control ops answered inline — they must not queue behind work.
    // Shutdown dispatches on the parsed op, never on raw request text.
    if op == "ping" {
        c.complete(seq, Value::object(vec![("ok", true.into())]).to_string());
        return LineOutcome::Continue;
    }
    if op == "shutdown" {
        c.complete(seq, Value::object(vec![("ok", true.into())]).to_string());
        return LineOutcome::Shutdown;
    }

    // Admission: deadline stamped here, so reactor queue time counts
    // against the budget.
    let queued = Instant::now();
    let deadline = state.deadline.and_then(|d| queued.checked_add(d));
    let job_state = state.clone();
    let job_comps = comps.clone();
    let generation = c.generation;
    let job = Box::new(move || {
        // A panicking dispatch must still push its completion — the
        // drain logic counts every submitted job, and the connection
        // would otherwise wait forever.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            dispatch(&op, &req, &job_state, queued, deadline, true)
        }));
        let line = match outcome {
            Ok(Ok(v)) => v.to_string(),
            Ok(Err(e)) => error_line(&e),
            Err(_) => error_line(&anyhow::anyhow!("internal error: request handler panicked")),
        };
        job_comps.push(Completion {
            token,
            generation,
            seq,
            line,
        });
    });
    match pool.submit(job) {
        Ok(()) => {
            c.inflight += 1;
            comps.note_submitted();
        }
        Err(SubmitError::Full(_)) => {
            state.note_rejected();
            c.complete(
                seq,
                error_line(&anyhow::anyhow!("server overloaded: admission queue full")),
            );
        }
        Err(SubmitError::Closed(_)) => {
            c.complete(seq, error_line(&anyhow::anyhow!("worker pool is shut down")));
        }
    }
    LineOutcome::Continue
}
