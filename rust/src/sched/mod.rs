//! Cross-query batch scheduler: coalesce concurrent queries' kernel work
//! into fused batches.
//!
//! The serving path PR 1/PR 2 built runs N concurrent queries that each
//! issue *batch-1* calls into kernels compiled to take many rows at once
//! (`proj_32`, `enc_8`, `sim_32x512`). This subsystem sits **between the
//! server front-end and the [`Engine`]**: queries submit per-stage work
//! items to queues, a batcher thread per stage closes a batch at the
//! kernel's native width or when a deadline (`batch_window_us`) expires,
//! executes **one fused kernel call**, and distributes the rows back over
//! completion channels.
//!
//! ```text
//!  client ──► admission (max_inflight) ──► bypass? ──► Engine::handle
//!                     │ no (≥2 in flight)
//!                     ▼
//!        [stage 1: embed queue]──batcher──► proj_{B}/enc_{B} (fused)
//!                     ▼
//!        [stage 2: probe queue]──batcher──► sim_{A}x{N} (fused, vs the
//!                     ▼                     lock-free ProbeTable snapshot)
//!        [stage 3: cluster walks + prefill + commit — per query, on the
//!                  submitting thread, via Engine::handle_prepared]
//! ```
//!
//! A third work-item kind — **on-demand cluster re-embedding** — flows
//! through an embed stage of its own: with batching enabled the builder
//! wires an [`EmbedBatcher`] into [`crate::index::EmbedSource::Live`],
//! so concurrent queries generating different clusters coalesce their
//! `proj_{B}`/`enc_{B}` calls too (a separate queue from query
//! embedding: cluster re-embeds are many-text items with different
//! latency needs, and they are submitted from under shard read leases).
//!
//! ## Latency, bypass and backpressure
//!
//! * **Bypass**: with at most one query in flight the scheduler calls
//!   [`Engine::handle`] directly — a lone query under light load pays
//!   zero batching latency and executes the exact unbatched path.
//! * **Deadline**: the oldest queued item waits at most `batch_window_us`
//!   before its partial batch executes; under saturation the deadline is
//!   already spent by the time the batcher looks, so batches close by
//!   width or by queue-drain without added waiting.
//! * **Backpressure**: admissions beyond `max_inflight` are rejected
//!   immediately (the error reaches the client as a normal protocol
//!   error), bounding queue depth and memory.
//! * **Query deadlines**: a query stamped with a deadline (by the server
//!   at admission, or via `deadline_us`) pulls its stage batches closed
//!   no later than that instant — the batch window orders by the
//!   *earliest rider deadline* — and is shed with a distinct "deadline
//!   exceeded" error if it expires while queued in a stage, so a
//!   saturated stage never burns fused-kernel time on answers nobody is
//!   waiting for.
//!
//! ## Equivalence
//!
//! Results are bit-identical to the unbatched path: the fused kernels
//! compute independent per-row results (`rust/src/runtime/reference.rs`
//! and the Pallas kernel contract), probing scores against the same
//! [`crate::index::ProbeTable`] snapshot the unbatched search uses, and
//! stage 3 runs the same walk/merge/commit code via
//! [`Engine::handle_prepared`].
//! Verified end to end by `rust/tests/sched_equivalence.rs`.
//!
//! ## Locks
//!
//! Stages hold **no** lease while queued or executing: the embed and
//! probe executors touch only shared services and immutable snapshots.
//! The engine read lease is taken (briefly) only inside stage 3 and when
//! fetching the probe snapshot — never across a batch wait. See
//! `docs/ARCHITECTURE.md` §"Batched execution model".

pub mod batcher;
pub mod stages;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::RetrievalConfig;
use crate::coordinator::{Engine, QueryOutcome};
use crate::index::Scorer;
use crate::trace::{self, TagValue};

pub use batcher::{BatchClose, BatchInfo, StageSnapshot};
pub use stages::{EmbedBatcher, ProbeBatcher};

/// Record one stage's wait/exec span pair into the calling thread's
/// active trace (no-op — one atomic load — when tracing is off).
///
/// The fused execution's wall time is attributed back to each rider as
/// an equal `exec_ns / width` share, with the batch's full width, close
/// reason and unshared cost carried as tags, so a slow query can show
/// whether it waited for a window, rode a full kernel batch, or paid an
/// inline execution.
pub(crate) fn record_stage_spans(wait: &'static str, exec: &'static str, info: &BatchInfo) {
    if !trace::active() {
        return;
    }
    trace::record(wait, info.wait_ns, &[("close", TagValue::Str(info.close.name()))]);
    let share = info.exec_ns / u64::from(info.width.max(1));
    trace::record(
        exec,
        share,
        &[
            ("width", TagValue::U64(u64::from(info.width))),
            ("close", TagValue::Str(info.close.name())),
            ("batch_ns", TagValue::U64(info.exec_ns)),
        ],
    );
}

/// Scheduler knobs (the `batching`/`batch_window_us`/`max_inflight`
/// fields of [`RetrievalConfig`], plus a test hook).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Deadline: the oldest queued work item waits at most this long
    /// before its partial batch executes.
    pub batch_window_us: u64,
    /// Queries admitted concurrently; further submissions are rejected
    /// with an "overloaded" error. 0 = unlimited.
    pub max_inflight: usize,
    /// Serve a lone query inline through the unbatched path (zero added
    /// latency under light load). Disabled by the equivalence tests to
    /// force every query through the fused kernels.
    pub bypass: bool,
    /// Per-query deadline in microseconds, stamped at admission when the
    /// caller didn't stamp one earlier ([`BatchScheduler::handle_at`]).
    /// Stage batches close no later than the earliest rider deadline,
    /// and an item already expired at dequeue is shed with a distinct
    /// "deadline exceeded" error. 0 = no deadline (library default).
    pub deadline_us: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            batch_window_us: 200,
            max_inflight: 256,
            bypass: true,
            deadline_us: 0,
        }
    }
}

impl SchedConfig {
    /// Lift the scheduler knobs out of a [`RetrievalConfig`].
    pub fn from_retrieval(r: &RetrievalConfig) -> SchedConfig {
        SchedConfig {
            batch_window_us: r.batch_window_us,
            max_inflight: r.max_inflight,
            bypass: true,
            deadline_us: r.resolved_deadline_us(),
        }
    }
}

/// Point-in-time scheduler statistics (the server's `stats` endpoint
/// exposes these when batching is enabled).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedStats {
    /// Queries submitted to the scheduler.
    pub submitted: u64,
    /// Queries served inline through the bypass path.
    pub bypassed: u64,
    /// Queries rejected by `max_inflight` backpressure.
    pub rejected: u64,
    /// Embed-stage counters (occupancy, window waits, …).
    pub embed: StageSnapshot,
    /// Probe-stage counters.
    pub probe: StageSnapshot,
}

/// RAII admission permit: holding one counts the query against
/// `max_inflight` until it completes (or errors).
pub struct InflightPermit<'a> {
    inflight: &'a AtomicUsize,
}

impl Drop for InflightPermit<'_> {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The cross-query batch scheduler. Sits in front of a shared
/// [`Engine`]; `handle` is `&self` and is called from as many server
/// workers as are configured.
pub struct BatchScheduler {
    engine: Arc<Engine>,
    embed: Arc<EmbedBatcher>,
    probe: ProbeBatcher,
    cfg: SchedConfig,
    inflight: AtomicUsize,
    submitted: AtomicU64,
    bypassed: AtomicU64,
    rejected: AtomicU64,
}

impl BatchScheduler {
    /// Build the scheduler over an engine: one embed stage (the engine's
    /// embedder backend at its widest compiled bucket) and one probe
    /// stage (the `sim_{A}x{N}` family at its widest query batch).
    pub fn new(engine: Arc<Engine>, cfg: SchedConfig) -> Arc<BatchScheduler> {
        let window = Duration::from_micros(cfg.batch_window_us);
        let embedder = engine.embedder().clone();
        let scorer = Scorer::new(embedder.compute().clone());
        let embed = EmbedBatcher::new(embedder, window);
        // Carried PR 3 lever: the engine's insert path embeds through
        // this same fused stage from now on — WAL'd inserts and served
        // queries take one embedding code path.
        engine.set_embed_stage(embed.clone());
        let probe = ProbeBatcher::new(scorer, window);
        Arc::new(BatchScheduler {
            engine,
            embed,
            probe,
            cfg,
            inflight: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            bypassed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        })
    }

    /// The engine this scheduler serves.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Admit one query against `max_inflight`, or fail with the
    /// overloaded error callers surface as a protocol error. Note: when
    /// the scheduler sits behind the server's worker pool, the pool's
    /// bounded admission queue (sized from the same `max_inflight` knob)
    /// rejects first — this check guards *direct* library callers that
    /// drive `handle` from unbounded thread counts.
    pub fn try_admit(&self) -> Result<InflightPermit<'_>> {
        let n = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if self.cfg.max_inflight > 0 && n > self.cfg.max_inflight {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!(
                "server overloaded: {n} queries in flight (max_inflight = {})",
                self.cfg.max_inflight
            );
        }
        Ok(InflightPermit {
            inflight: &self.inflight,
        })
    }

    /// Serve one query end to end through the staged path (or the bypass
    /// under light load). Results are bit-identical to
    /// [`Engine::handle`].
    pub fn handle(&self, text: &str) -> Result<QueryOutcome> {
        self.handle_at(text, None)
    }

    /// [`BatchScheduler::handle`] with an explicit query deadline. The
    /// server stamps the deadline at admission (so front-end queue time
    /// counts against it); `None` falls back to `cfg.deadline_us` from
    /// this call's entry, and 0 means no deadline. Stage batches close
    /// no later than the earliest rider deadline; an item that expires
    /// while queued in a stage is shed with a distinct "deadline
    /// exceeded" error (counted in the stage's `shed` counter) instead
    /// of executed. Deadline stamping never perturbs the *results* of
    /// queries that do execute — they stay bit-identical to
    /// [`Engine::handle`].
    pub fn handle_at(&self, text: &str, deadline: Option<Instant>) -> Result<QueryOutcome> {
        let wall_start = Instant::now();
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let deadline = deadline.or_else(|| {
            (self.cfg.deadline_us > 0)
                .then(|| wall_start.checked_add(Duration::from_micros(self.cfg.deadline_us)))
                .flatten()
        });
        let _permit = self.try_admit()?;

        // Lone query: the staged path cannot help (nothing to coalesce
        // with) — serve the exact unbatched path, zero added latency.
        if self.cfg.bypass && self.inflight.load(Ordering::SeqCst) <= 1 {
            self.bypassed.fetch_add(1, Ordering::Relaxed);
            trace::record_event("sched.bypass", &[]);
            return self.engine.handle(text);
        }

        // Stage 1: fused query embedding.
        let (q, embed_info) = self.embed.embed_one_info_at(text, deadline);
        record_stage_spans("embed.wait", "embed.exec", &embed_info);
        let q = q?;

        // Stage 2: fused centroid probe against the lock-free snapshot.
        // The engine read lease is held only to clone the snapshot Arc,
        // never across the batch wait.
        let table = { self.engine.index().probe_table() };
        let probe = match table {
            Some(table) => {
                let (scores, probe_info) =
                    self.probe.scores_info_at(q.clone(), table.clone(), deadline);
                record_stage_spans("probe.wait", "probe.exec", &probe_info);
                let scores = scores?;
                Some((table, scores))
            }
            None => None, // flat baseline: no centroid level to batch
        };

        // Stage 3: cluster walks, chunk fetch, prefill and commit on the
        // submitting thread — per-query state stays on this stack.
        let probe_ref = probe
            .as_ref()
            .map(|(t, s)| (t.as_ref(), s.as_slice()));
        self.engine.handle_prepared(text, &q, probe_ref, wall_start)
    }

    /// Record an admission rejection made on the scheduler's behalf (the
    /// server's bounded worker-pool queue rejects *before* a worker can
    /// call [`BatchScheduler::handle`]; counting it here keeps the
    /// `rejected` stat meaning "requests turned away by overload
    /// control" regardless of which layer fired).
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Scheduler + per-stage statistics.
    pub fn stats(&self) -> SchedStats {
        SchedStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            bypassed: self.bypassed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            embed: self.embed.snapshot(),
            probe: self.probe.snapshot(),
        }
    }

    /// Close both stages: queued work is flushed and completes; later
    /// queries execute inline (unbatched) — a draining server keeps
    /// answering. Idempotent.
    pub fn shutdown(&self) {
        self.embed.shutdown();
        self.probe.shutdown();
    }
}
