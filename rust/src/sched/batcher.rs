//! The generic per-stage micro-batcher: one queue, one batcher thread,
//! one fused execution per closed batch.
//!
//! ## Batch-closing policy
//!
//! A batch closes when the first of these happens:
//!
//! * it reaches the stage's **width** (the kernel's native batch size);
//! * the **earliest rider deadline** expires. Each item's close instant
//!   is `enqueued + batch_window`, pulled *earlier* when the item
//!   carries a query deadline tighter than its window
//!   ([`Batcher::submit_at`]); the batch executes at the minimum over
//!   its riders, so one urgent query drags the whole partial batch
//!   forward instead of waiting out the fixed window. Under continuous
//!   load the oldest item typically queued while the previous batch
//!   executed, so its close instant is already (nearly) spent and the
//!   batcher drains whatever is queued and executes immediately — the
//!   window only *delays* sparse traffic, it never throttles a
//!   saturated stage;
//! * the stage shuts down — queued items are **flushed** (executed, not
//!   errored) so a clean shutdown completes in-flight work.
//!
//! ## Deadline shedding
//!
//! An item whose query deadline has **already expired when the batcher
//! dequeues it** is shed: its caller gets a distinct "deadline exceeded"
//! error immediately ([`BatchClose::Shed`], counted in
//! [`StageSnapshot::shed`]) and the fused kernel never pays for work
//! nobody is waiting on. Items without a deadline (the library default)
//! are never shed.
//!
//! Callers block on a per-item completion channel; the batcher thread is
//! the only place the fused executor runs. Executors must not take any
//! index or engine lease (the stage executors score/embed against
//! snapshots and shared services only), which keeps the batcher outside
//! the lock hierarchy entirely — a caller waiting in a batch can hold a
//! shard read lease (cluster re-embedding) without risking deadlock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

/// Live counters of one stage (all monotone).
#[derive(Debug, Default)]
pub(crate) struct StageCounters {
    submitted: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    full_width: AtomicU64,
    window_expired: AtomicU64,
    shed: AtomicU64,
}

/// A point-in-time view of one stage's counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageSnapshot {
    /// Items submitted to the stage.
    pub submitted: u64,
    /// Fused executions.
    pub batches: u64,
    /// Items that went through fused executions.
    pub batched_items: u64,
    /// Batches that closed at the kernel's full width.
    pub full_width: u64,
    /// Batches that closed because the deadline expired.
    pub window_expired: u64,
    /// Items shed at dequeue because their query deadline had already
    /// expired (they never reached a fused execution).
    pub shed: u64,
}

impl StageSnapshot {
    /// Mean items per fused execution (batch occupancy).
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }
}

/// Why a batch stopped accepting items and executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchClose {
    /// Reached the kernel's full width.
    Full,
    /// The oldest item's deadline expired.
    Window,
    /// The submit side disconnected mid-collection.
    Drain,
    /// Stage shutdown flushed the queue.
    Shutdown,
    /// Never batched: executed inline by the caller (stage refused or
    /// the scheduler bypassed batching).
    Inline,
    /// Never executed: the item's query deadline had already expired
    /// when the batcher dequeued it.
    Shed,
}

impl BatchClose {
    /// Stable lowercase label (trace tags, metrics).
    pub fn name(self) -> &'static str {
        match self {
            BatchClose::Full => "full",
            BatchClose::Window => "window",
            BatchClose::Drain => "drain",
            BatchClose::Shutdown => "shutdown",
            BatchClose::Inline => "inline",
            BatchClose::Shed => "shed",
        }
    }
}

/// How one item's batch went: the attribution record each caller gets
/// back with its result, so a traced query can account its share of the
/// fused execution it rode in.
#[derive(Debug, Clone, Copy)]
pub struct BatchInfo {
    /// Items in the fused execution (1 for inline).
    pub width: u32,
    /// Why the batch closed.
    pub close: BatchClose,
    /// Wall time of the fused execution, shared by all `width` items.
    pub exec_ns: u64,
    /// This item's enqueue-to-execution wait.
    pub wait_ns: u64,
}

impl BatchInfo {
    /// Attribution record for work executed inline (unbatched).
    pub fn inline(exec_ns: u64) -> BatchInfo {
        BatchInfo {
            width: 1,
            close: BatchClose::Inline,
            exec_ns,
            wait_ns: 0,
        }
    }
}

struct Item<I, O> {
    input: I,
    enqueued: Instant,
    /// The rider's query deadline: pulls the batch close earlier than
    /// the window and sheds the item if already expired at dequeue.
    deadline: Option<Instant>,
    reply: mpsc::Sender<(Result<O>, BatchInfo)>,
}

/// Outcome of a submission attempt.
pub(crate) enum Submit<O, I> {
    /// The item went through a (possibly fused) batch; the
    /// [`BatchInfo`] says how wide it was, why it closed, and how long
    /// this item waited and executed.
    Done(Result<O>, BatchInfo),
    /// The stage is shut down; the input is handed back so the caller
    /// can execute it inline (unbatched) — queries never fail just
    /// because batching stopped.
    Refused(I),
}

/// One stage: submit work items, get each one's slice of a fused result.
pub(crate) struct Batcher<I: Send + 'static, O: Send + 'static> {
    /// `None` once the stage is shut down. The mutex is held only for
    /// the (non-blocking) enqueue.
    tx: Mutex<Option<mpsc::Sender<Item<I, O>>>>,
    counters: Arc<StageCounters>,
}

impl<I: Send + 'static, O: Send + 'static> Batcher<I, O> {
    /// Spawn the stage. `exec` receives a closed batch's inputs and must
    /// return exactly one result per input, in order.
    pub(crate) fn new<F>(name: &str, width: usize, window: Duration, exec: F) -> Batcher<I, O>
    where
        F: Fn(&[I]) -> Vec<Result<O>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Item<I, O>>();
        let counters = Arc::new(StageCounters::default());
        let c = counters.clone();
        let width = width.max(1);
        std::thread::Builder::new()
            .name(format!("edgerag-batch-{name}"))
            .spawn(move || batch_loop(rx, width, window, exec, c))
            .expect("spawning stage batcher thread");
        Batcher {
            tx: Mutex::new(Some(tx)),
            counters,
        }
    }

    /// Submit one item and block until its batch has executed. A shut
    /// stage refuses and hands the input back for inline execution.
    pub(crate) fn submit(&self, input: I) -> Submit<O, I> {
        self.submit_at(input, None)
    }

    /// [`Batcher::submit`] with a query deadline: the batch holding this
    /// item closes no later than `deadline`, and if the deadline has
    /// already expired when the batcher dequeues the item it is shed
    /// with a "deadline exceeded" error instead of executed.
    pub(crate) fn submit_at(&self, input: I, deadline: Option<Instant>) -> Submit<O, I> {
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        {
            let guard = self.tx.lock().unwrap();
            let Some(tx) = guard.as_ref() else {
                return Submit::Refused(input);
            };
            if let Err(e) = tx.send(Item {
                input,
                enqueued: Instant::now(),
                deadline,
                reply,
            }) {
                return Submit::Refused(e.0.input);
            }
        }
        match rx.recv() {
            Ok((result, info)) => Submit::Done(result, info),
            Err(_) => Submit::Done(
                Err(anyhow::anyhow!("batch stage dropped the reply")),
                BatchInfo::inline(0),
            ),
        }
    }

    /// Close the stage: already-queued items are flushed as final
    /// batches; later submissions are refused (callers run inline).
    pub(crate) fn shutdown(&self) {
        *self.tx.lock().unwrap() = None;
    }

    pub(crate) fn snapshot(&self) -> StageSnapshot {
        StageSnapshot {
            submitted: self.counters.submitted.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            batched_items: self.counters.batched_items.load(Ordering::Relaxed),
            full_width: self.counters.full_width.load(Ordering::Relaxed),
            window_expired: self.counters.window_expired.load(Ordering::Relaxed),
            shed: self.counters.shed.load(Ordering::Relaxed),
        }
    }
}

impl<I: Send + 'static, O: Send + 'static> Drop for Batcher<I, O> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batch_loop<I, O, F>(
    rx: mpsc::Receiver<Item<I, O>>,
    width: usize,
    window: Duration,
    exec: F,
    counters: Arc<StageCounters>,
) where
    F: Fn(&[I]) -> Vec<Result<O>>,
{
    let mut open = true;
    while open {
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break, // stage shut down with an empty queue
        };
        let mut batch = Vec::with_capacity(width);
        admit_or_shed(first, &mut batch, &counters);
        // Greedy drain: take whatever queued while the previous batch
        // executed.
        while batch.len() < width {
            match rx.try_recv() {
                Ok(item) => admit_or_shed(item, &mut batch, &counters),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // Close instant: wait for stragglers only until the earliest
        // rider close — `enqueued + window`, pulled forward by any rider
        // whose query deadline is tighter than its window.
        let mut close = if batch.len() >= width {
            BatchClose::Full
        } else if !open {
            BatchClose::Drain
        } else {
            BatchClose::Window // zero window: the close instant is already spent
        };
        if open && !batch.is_empty() && batch.len() < width && !window.is_zero() {
            loop {
                if batch.len() >= width {
                    close = BatchClose::Full;
                    break;
                }
                // Recomputed every admission: a late rider with a tight
                // deadline pulls the whole partial batch forward.
                let deadline = earliest_close(&batch, window);
                let now = Instant::now();
                if now >= deadline {
                    close = BatchClose::Window;
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(item) => admit_or_shed(item, &mut batch, &counters),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        counters.window_expired.fetch_add(1, Ordering::Relaxed);
                        close = BatchClose::Window;
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        close = BatchClose::Drain;
                        break;
                    }
                }
            }
        }
        if batch.is_empty() {
            continue; // everything dequeued this round was shed
        }
        run_batch(batch, width, &exec, &counters, close);
    }
    // Clean shutdown with items queued: flush the remainder so every
    // blocked caller completes.
    loop {
        let mut batch = Vec::new();
        let mut drained_any = false;
        while batch.len() < width {
            match rx.try_recv() {
                Ok(item) => {
                    drained_any = true;
                    admit_or_shed(item, &mut batch, &counters);
                }
                Err(_) => break,
            }
        }
        if !batch.is_empty() {
            run_batch(batch, width, &exec, &counters, BatchClose::Shutdown);
        } else if !drained_any {
            break;
        }
    }
}

/// The earliest instant any rider requires the batch to close:
/// `min(enqueued + window, query deadline)` over the batch. Only called
/// on non-empty batches.
fn earliest_close<I, O>(batch: &[Item<I, O>], window: Duration) -> Instant {
    batch
        .iter()
        .map(|item| {
            let windowed = item.enqueued + window;
            match item.deadline {
                Some(d) if d < windowed => d,
                _ => windowed,
            }
        })
        .min()
        .expect("earliest_close on a non-empty batch")
}

/// Admit one dequeued item into the forming batch, or shed it with a
/// "deadline exceeded" error if its query deadline has already expired.
fn admit_or_shed<I, O>(item: Item<I, O>, batch: &mut Vec<Item<I, O>>, counters: &StageCounters) {
    if let Some(d) = item.deadline {
        if Instant::now() >= d {
            counters.shed.fetch_add(1, Ordering::Relaxed);
            let info = BatchInfo {
                width: 1,
                close: BatchClose::Shed,
                exec_ns: 0,
                wait_ns: item.enqueued.elapsed().as_nanos() as u64,
            };
            let _ = item.reply.send((
                Err(anyhow::anyhow!(
                    "deadline exceeded: work item expired in the stage queue before its batch dequeued"
                )),
                info,
            ));
            return;
        }
    }
    batch.push(item);
}

fn run_batch<I, O, F>(
    batch: Vec<Item<I, O>>,
    width: usize,
    exec: &F,
    counters: &StageCounters,
    close: BatchClose,
) where
    F: Fn(&[I]) -> Vec<Result<O>>,
{
    counters.batches.fetch_add(1, Ordering::Relaxed);
    counters
        .batched_items
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    if batch.len() >= width {
        counters.full_width.fetch_add(1, Ordering::Relaxed);
    }
    let mut inputs = Vec::with_capacity(batch.len());
    let mut replies = Vec::with_capacity(batch.len());
    for item in batch {
        inputs.push(item.input);
        replies.push((item.reply, item.enqueued));
    }
    // Timed unconditionally: two timestamps per *batch*, amortized over
    // its width, keep the attribution record accurate whether or not
    // the caller's query is traced.
    let run_start = Instant::now();
    let outputs = exec(&inputs);
    let exec_ns = run_start.elapsed().as_nanos() as u64;
    let batch_width = inputs.len() as u32;
    let info_for = |enqueued: Instant| BatchInfo {
        width: batch_width,
        close,
        exec_ns,
        wait_ns: run_start.saturating_duration_since(enqueued).as_nanos() as u64,
    };
    let produced = outputs.len();
    for ((reply, enqueued), out) in replies.iter().zip(outputs) {
        let _ = reply.send((out, info_for(*enqueued))); // a caller that gave up is fine to miss
    }
    for (reply, enqueued) in replies.iter().skip(produced) {
        let _ = reply.send((
            Err(anyhow::anyhow!(
                "stage executor returned {produced} results for a larger batch"
            )),
            info_for(*enqueued),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doubler(width: usize, window: Duration) -> Batcher<u64, u64> {
        Batcher::new("test", width, window, |xs: &[u64]| {
            xs.iter().map(|&x| Ok(x * 2)).collect()
        })
    }

    fn must(s: Submit<u64, u64>) -> u64 {
        match s {
            Submit::Done(r, _) => r.unwrap(),
            Submit::Refused(_) => panic!("stage unexpectedly shut down"),
        }
    }

    fn must_info(s: Submit<u64, u64>) -> (u64, BatchInfo) {
        match s {
            Submit::Done(r, info) => (r.unwrap(), info),
            Submit::Refused(_) => panic!("stage unexpectedly shut down"),
        }
    }

    #[test]
    fn single_item_executes_within_window() {
        let b = doubler(32, Duration::from_millis(20));
        let start = Instant::now();
        assert_eq!(must(b.submit(21)), 42);
        assert!(start.elapsed() < Duration::from_secs(5));
        let s = b.snapshot();
        assert_eq!((s.submitted, s.batches, s.batched_items), (1, 1, 1));
        assert_eq!(s.window_expired, 1, "a lone item closes by deadline");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        // Width far above the offered load: the deadline must close the
        // batch, and concurrent submitters must coalesce into it.
        let b = Arc::new(doubler(32, Duration::from_millis(60)));
        let mut handles = Vec::new();
        for i in 0..3u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || must(b.submit(i))));
        }
        let mut got: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 2, 4]);
        let s = b.snapshot();
        assert!(s.window_expired >= 1, "{s:?}");
        assert!(s.batches <= 3, "{s:?}");
        assert_eq!(s.batched_items, 3);
    }

    #[test]
    fn width_closes_batch_without_waiting() {
        let b = Arc::new(doubler(2, Duration::from_secs(30)));
        let start = Instant::now();
        let mut handles = Vec::new();
        for i in 0..4u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || must(b.submit(i))));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Four items over width-2 batches: at most two full batches plus
        // at most one deadline... but with a 30s window, finishing fast
        // proves width (not the window) closed the batches.
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "width must close batches long before the 30s window"
        );
        let s = b.snapshot();
        assert!(s.full_width >= 1, "{s:?}");
    }

    #[test]
    fn batch_info_reports_width_and_close_reason() {
        // Lone item under a huge width: the deadline closes the batch.
        let b = doubler(32, Duration::from_millis(20));
        let (out, info) = must_info(b.submit(21));
        assert_eq!(out, 42);
        assert_eq!(info.width, 1);
        assert_eq!(info.close, BatchClose::Window);

        // Width 1: every submission closes a full batch immediately.
        let b = doubler(1, Duration::from_secs(30));
        let (_, info) = must_info(b.submit(3));
        assert_eq!(info.width, 1);
        assert_eq!(info.close, BatchClose::Full);
    }

    #[test]
    fn expired_deadline_sheds_at_dequeue() {
        let b = doubler(32, Duration::from_millis(20));
        // A deadline already in the past: the batcher must shed the item
        // with a distinct error, never running the executor for it.
        let past = Instant::now() - Duration::from_millis(5);
        match b.submit_at(7, Some(past)) {
            Submit::Done(result, info) => {
                let err = result.unwrap_err();
                assert!(
                    format!("{err:#}").contains("deadline exceeded"),
                    "unexpected error: {err:#}"
                );
                assert_eq!(info.close, BatchClose::Shed);
            }
            Submit::Refused(_) => panic!("stage unexpectedly shut down"),
        }
        let s = b.snapshot();
        assert_eq!(s.shed, 1);
        assert_eq!(s.batches, 0, "shed items never reach a fused execution");
        // The stage stays healthy: a deadline-free item still executes.
        assert_eq!(must(b.submit(21)), 42);
    }

    #[test]
    fn tight_rider_deadline_closes_batch_before_window() {
        // A 30s window would hold a lone rider forever; its 50ms query
        // deadline must pull the close forward.
        let b = doubler(32, Duration::from_secs(30));
        let start = Instant::now();
        let deadline = Instant::now() + Duration::from_millis(50);
        assert_eq!(must(b.submit_at(21, Some(deadline))), 42);
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "the rider deadline must close the batch, not the 30s window"
        );
        let s = b.snapshot();
        assert_eq!(s.shed, 0, "the item was live at dequeue");
        assert_eq!(s.batched_items, 1);
    }

    #[test]
    fn shutdown_flushes_queued_items() {
        // A huge window would hold the lone queued item for 30s; shutdown
        // must flush it promptly instead of erroring it.
        let b = Arc::new(doubler(32, Duration::from_secs(30)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || must(b2.submit(5)));
        std::thread::sleep(Duration::from_millis(100)); // let it enqueue
        let start = Instant::now();
        b.shutdown();
        assert_eq!(h.join().unwrap(), 10, "queued item completes on shutdown");
        assert!(start.elapsed() < Duration::from_secs(10));
        assert!(
            matches!(b.submit(1), Submit::Refused(1)),
            "submissions after shutdown are refused with the input"
        );
    }
}
