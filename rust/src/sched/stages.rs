//! The concrete batch stages: cross-query embedding and cross-query
//! centroid-probe scoring.
//!
//! Each stage wraps the generic `Batcher` (see [`crate::sched::batcher`])
//! around one fused kernel entry point:
//!
//! * **embed** — [`Embedder::embed_requests`]: all requests' texts run
//!   through one shape-bucketed `proj_{B}` / `enc_{B}` pass. Work items
//!   are whole requests (a query's single text, or a cluster
//!   re-embedding's member texts), so the serving path and the online
//!   generation path share one stage.
//! * **probe** — [`Scorer::scores_multi`]: queries that probe the same
//!   [`ProbeTable`] snapshot score in one fused `sim_{A}x{N}` call;
//!   queries holding different snapshots (a structural update landed
//!   between them) fall into separate fused calls within the same batch.
//!
//! Both executors touch only shared services and immutable snapshots —
//! never an index or engine lease — so stages compose with the lock
//! hierarchy trivially (see `docs/ARCHITECTURE.md`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::embedding::Embedder;
use crate::index::{ProbeTable, Scorer};
use crate::sched::batcher::{BatchInfo, Batcher, StageSnapshot, Submit};
use crate::vecmath::EmbeddingMatrix;

// ---------------------------------------------------------------------------
// Embed stage
// ---------------------------------------------------------------------------

/// A fused embedding stage. Two instances serve a batching-enabled
/// system: the scheduler's query-embedding stage, and the stage the
/// builder wires into [`crate::index::EmbedSource::Live`] for on-demand
/// cluster re-embedding (separate queues — see [`crate::sched`] module
/// docs).
pub struct EmbedBatcher {
    batcher: Batcher<Vec<String>, EmbeddingMatrix>,
    /// Inline fallback once the stage is shut down (a drained server
    /// keeps answering, just unbatched).
    embedder: Embedder,
}

impl EmbedBatcher {
    /// Spawn the stage over `embedder`'s kernels. Width is the widest
    /// compiled batch bucket of the active backend.
    pub fn new(embedder: Embedder, window: Duration) -> Arc<EmbedBatcher> {
        let width = embedder.max_batch().max(2);
        let exec_embedder = embedder.clone();
        let batcher = Batcher::new("embed", width, window, move |reqs: &[Vec<String>]| {
            match exec_embedder.embed_requests(reqs) {
                Ok(mats) => mats.into_iter().map(Ok).collect(),
                Err(e) => {
                    let msg = format!("{e:#}");
                    reqs.iter()
                        .map(|_| Err(anyhow::anyhow!("fused embed failed: {msg}")))
                        .collect()
                }
            }
        });
        Arc::new(EmbedBatcher { batcher, embedder })
    }

    /// Embed one request's texts through the fused stage (blocks until
    /// the request's batch executes; runs inline when the stage is shut
    /// down).
    pub fn embed_texts(&self, texts: &[&str]) -> Result<EmbeddingMatrix> {
        self.embed_texts_info(texts).0
    }

    /// Like [`EmbedBatcher::embed_texts`], also returning the
    /// [`BatchInfo`] attribution record (batch width, close reason,
    /// fused-execution and wait times) for trace accounting.
    pub fn embed_texts_info(&self, texts: &[&str]) -> (Result<EmbeddingMatrix>, BatchInfo) {
        self.embed_texts_info_at(texts, None)
    }

    /// [`EmbedBatcher::embed_texts_info`] with an optional query
    /// deadline: the stage closes this rider's batch no later than the
    /// deadline and sheds the item (distinct "deadline exceeded" error)
    /// if it is already expired at dequeue. The inline fallback for a
    /// shut stage runs regardless of deadline — shutdown drains always
    /// complete.
    pub fn embed_texts_info_at(
        &self,
        texts: &[&str],
        deadline: Option<Instant>,
    ) -> (Result<EmbeddingMatrix>, BatchInfo) {
        match self
            .batcher
            .submit_at(texts.iter().map(|s| s.to_string()).collect(), deadline)
        {
            Submit::Done(r, info) => (r, info),
            Submit::Refused(owned) => {
                let refs: Vec<&str> = owned.iter().map(|s| s.as_str()).collect();
                let started = Instant::now();
                let r = self.embedder.embed_texts(&refs);
                (r, BatchInfo::inline(started.elapsed().as_nanos() as u64))
            }
        }
    }

    /// Embed a single text (the query-embedding work item).
    pub fn embed_one(&self, text: &str) -> Result<Vec<f32>> {
        self.embed_one_info(text).0
    }

    /// Like [`EmbedBatcher::embed_one`], also returning the batch
    /// attribution record.
    pub fn embed_one_info(&self, text: &str) -> (Result<Vec<f32>>, BatchInfo) {
        self.embed_one_info_at(text, None)
    }

    /// [`EmbedBatcher::embed_one_info`] with an optional query deadline
    /// (see [`EmbedBatcher::embed_texts_info_at`]).
    pub fn embed_one_info_at(
        &self,
        text: &str,
        deadline: Option<Instant>,
    ) -> (Result<Vec<f32>>, BatchInfo) {
        let (r, info) = self.embed_texts_info_at(&[text], deadline);
        let row = r.and_then(|m| {
            anyhow::ensure!(m.len() == 1, "fused embed returned {} rows for 1 text", m.len());
            Ok(m.row(0).to_vec())
        });
        (row, info)
    }

    /// Stage counters.
    pub fn snapshot(&self) -> StageSnapshot {
        self.batcher.snapshot()
    }

    /// Close the stage (queued requests still complete).
    pub fn shutdown(&self) {
        self.batcher.shutdown()
    }
}

// ---------------------------------------------------------------------------
// Probe stage
// ---------------------------------------------------------------------------

/// One probe work item: the query vector plus the snapshot it probes.
type ProbeItem = (Vec<f32>, Arc<ProbeTable>);

/// The fused centroid-probe stage: `(query, snapshot)` in, masked global
/// score table out.
pub struct ProbeBatcher {
    batcher: Batcher<ProbeItem, Vec<f32>>,
    /// Inline fallback once the stage is shut down.
    scorer: Scorer,
}

impl ProbeBatcher {
    /// Spawn the stage over `scorer`'s `sim_{A}x{N}` family. Width is the
    /// widest compiled query batch.
    pub fn new(scorer: Scorer, window: Duration) -> ProbeBatcher {
        let width = scorer.max_sim_batch().max(2);
        let exec_scorer = scorer.clone();
        let batcher = Batcher::new(
            "probe",
            width,
            window,
            move |items: &[ProbeItem]| {
                let mut out: Vec<Option<Result<Vec<f32>>>> =
                    items.iter().map(|_| None).collect();
                // Group by snapshot identity: one fused kernel call per
                // distinct table (normally exactly one group).
                let mut remaining: Vec<usize> = (0..items.len()).collect();
                while let Some(&lead) = remaining.first() {
                    let table = items[lead].1.clone();
                    let group: Vec<usize> = remaining
                        .iter()
                        .copied()
                        .filter(|&i| Arc::ptr_eq(&items[i].1, &table))
                        .collect();
                    remaining.retain(|i| !group.contains(i));
                    let queries: Vec<&[f32]> =
                        group.iter().map(|&i| items[i].0.as_slice()).collect();
                    match exec_scorer.scores_multi(&queries, &table.centroids) {
                        Ok(scored) => {
                            for (&gi, mut s) in group.iter().zip(scored) {
                                table.mask(&mut s);
                                out[gi] = Some(Ok(s));
                            }
                        }
                        Err(e) => {
                            let msg = format!("{e:#}");
                            for &gi in &group {
                                out[gi] =
                                    Some(Err(anyhow::anyhow!("fused probe failed: {msg}")));
                            }
                        }
                    }
                }
                out.into_iter()
                    .map(|o| o.expect("every batch item grouped"))
                    .collect()
            },
        );
        ProbeBatcher { batcher, scorer }
    }

    /// Masked centroid scores of `query` against `table`, computed in a
    /// fused batch with whatever other queries are in flight (inline
    /// when the stage is shut down).
    pub fn scores(&self, query: Vec<f32>, table: Arc<ProbeTable>) -> Result<Vec<f32>> {
        self.scores_info(query, table).0
    }

    /// Like [`ProbeBatcher::scores`], also returning the [`BatchInfo`]
    /// attribution record for trace accounting.
    pub fn scores_info(
        &self,
        query: Vec<f32>,
        table: Arc<ProbeTable>,
    ) -> (Result<Vec<f32>>, BatchInfo) {
        self.scores_info_at(query, table, None)
    }

    /// [`ProbeBatcher::scores_info`] with an optional query deadline:
    /// the batch closes no later than the deadline, and an item already
    /// expired at dequeue is shed with a "deadline exceeded" error.
    pub fn scores_info_at(
        &self,
        query: Vec<f32>,
        table: Arc<ProbeTable>,
        deadline: Option<Instant>,
    ) -> (Result<Vec<f32>>, BatchInfo) {
        match self.batcher.submit_at((query, table), deadline) {
            Submit::Done(r, info) => (r, info),
            Submit::Refused((q, table)) => {
                let started = Instant::now();
                let r = table.masked_scores(&self.scorer, &q);
                (r, BatchInfo::inline(started.elapsed().as_nanos() as u64))
            }
        }
    }

    /// Stage counters.
    pub fn snapshot(&self) -> StageSnapshot {
        self.batcher.snapshot()
    }

    /// Close the stage (queued probes still complete).
    pub fn shutdown(&self) {
        self.batcher.shutdown()
    }
}
