//! Shared chunk-text store: the corpus texts plus any chunks ingested
//! online (§5.4). The retrieval pipeline reads it on every prompt
//! assembly; the server appends on `insert`.

use std::sync::{Arc, RwLock};

#[derive(Clone)]
pub struct TextStore {
    inner: Arc<RwLock<Vec<String>>>,
}

impl TextStore {
    pub fn new(texts: Vec<String>) -> Self {
        TextStore {
            inner: Arc::new(RwLock::new(texts)),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, id: u32) -> Option<String> {
        self.inner.read().unwrap().get(id as usize).cloned()
    }

    /// Append a new chunk's text, returning its id.
    pub fn push(&self, text: String) -> u32 {
        let mut v = self.inner.write().unwrap();
        v.push(text);
        (v.len() - 1) as u32
    }

    /// Fetch several texts at once (prompt assembly).
    pub fn get_many(&self, ids: &[u32]) -> Vec<String> {
        let v = self.inner.read().unwrap();
        ids.iter()
            .filter_map(|&id| v.get(id as usize).cloned())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_get() {
        let s = TextStore::new(vec!["a".into(), "b".into()]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(1).as_deref(), Some("b"));
        let id = s.push("c".into());
        assert_eq!(id, 2);
        assert_eq!(s.get(2).as_deref(), Some("c"));
        assert_eq!(s.get(99), None);
    }

    #[test]
    fn get_many_skips_missing() {
        let s = TextStore::new(vec!["a".into()]);
        assert_eq!(s.get_many(&[0, 5]), vec!["a".to_string()]);
    }

    #[test]
    fn shared_across_clones() {
        let s = TextStore::new(vec![]);
        let s2 = s.clone();
        s.push("x".into());
        assert_eq!(s2.len(), 1);
    }
}
