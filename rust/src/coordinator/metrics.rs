//! Serving metrics: latency recording, percentiles, per-component
//! breakdowns, SLO attainment. The figure benches read these; the server
//! exposes them on its stats endpoint.

use std::collections::HashMap;

use crate::simtime::{Breakdown, Component, SimDuration};

/// A recorded latency series with exact percentile queries (we keep raw
/// samples — workloads are ≤ thousands of queries, exactness beats
/// HDR-style bucketing at this scale).
#[derive(Debug, Clone, Default)]
pub struct LatencySeries {
    samples_ns: Vec<u64>,
    sorted: bool,
}

impl LatencySeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: SimDuration) {
        self.samples_ns.push(d.as_nanos());
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ns.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_ns.sort_unstable();
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank), `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> SimDuration {
        if self.samples_ns.is_empty() {
            return SimDuration::ZERO;
        }
        self.ensure_sorted();
        let n = self.samples_ns.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        SimDuration::from_nanos(self.samples_ns[rank.min(n) - 1])
    }

    pub fn median(&mut self) -> SimDuration {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> SimDuration {
        if self.samples_ns.is_empty() {
            return SimDuration::ZERO;
        }
        let sum: u128 = self.samples_ns.iter().map(|&x| x as u128).sum();
        SimDuration::from_nanos((sum / self.samples_ns.len() as u128) as u64)
    }

    pub fn max(&mut self) -> SimDuration {
        self.ensure_sorted();
        SimDuration::from_nanos(self.samples_ns.last().copied().unwrap_or(0))
    }

    /// Fraction of samples at or below `slo`.
    pub fn slo_attainment(&self, slo: SimDuration) -> f64 {
        if self.samples_ns.is_empty() {
            return 1.0;
        }
        let ok = self
            .samples_ns
            .iter()
            .filter(|&&s| s <= slo.as_nanos())
            .count();
        ok as f64 / self.samples_ns.len() as f64
    }

    /// CDF points (latency, cumulative fraction) — Fig. 12's distribution.
    pub fn cdf(&mut self, points: usize) -> Vec<(SimDuration, f64)> {
        if self.samples_ns.is_empty() {
            return Vec::new();
        }
        self.ensure_sorted();
        let n = self.samples_ns.len();
        (1..=points)
            .map(|i| {
                let frac = i as f64 / points as f64;
                let idx = ((frac * n as f64).ceil() as usize).min(n) - 1;
                (SimDuration::from_nanos(self.samples_ns[idx]), frac)
            })
            .collect()
    }
}

/// Full per-run metrics: TTFT + retrieval series, component sums, event
/// counters.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub retrieval: LatencySeries,
    pub ttft: LatencySeries,
    component_ns: HashMap<&'static str, u64>,
    counters: HashMap<&'static str, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_query(&mut self, breakdown: &Breakdown, retrieval: SimDuration, ttft: SimDuration) {
        self.retrieval.record(retrieval);
        self.ttft.record(ttft);
        for c in Component::ALL {
            let ns = breakdown.get(c).as_nanos();
            if ns > 0 {
                *self.component_ns.entry(c.name()).or_insert(0) += ns;
            }
        }
    }

    pub fn bump(&mut self, counter: &'static str, by: u64) {
        *self.counters.entry(counter).or_insert(0) += by;
    }

    pub fn counter(&self, counter: &str) -> u64 {
        self.counters.get(counter).copied().unwrap_or(0)
    }

    pub fn component_total(&self, c: Component) -> SimDuration {
        SimDuration::from_nanos(self.component_ns.get(c.name()).copied().unwrap_or(0))
    }

    /// Mean per-query time in component `c`.
    pub fn component_mean(&self, c: Component) -> SimDuration {
        let n = self.retrieval.len().max(1) as u64;
        SimDuration::from_nanos(self.component_total(c).as_nanos() / n)
    }

    pub fn queries(&self) -> usize {
        self.retrieval.len()
    }

    /// Drop all recorded samples/counters (post-warmup reset).
    pub fn reset(&mut self) {
        *self = Metrics::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::LatencyLedger;

    fn ms(v: u64) -> SimDuration {
        SimDuration::from_millis(v)
    }

    #[test]
    fn percentiles_exact() {
        let mut s = LatencySeries::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            s.record(ms(v));
        }
        assert_eq!(s.median(), ms(50));
        assert_eq!(s.percentile(95.0), ms(100));
        assert_eq!(s.percentile(10.0), ms(10));
        assert_eq!(s.max(), ms(100));
        assert_eq!(s.mean(), ms(55));
    }

    #[test]
    fn percentile_of_singleton() {
        let mut s = LatencySeries::new();
        s.record(ms(42));
        assert_eq!(s.median(), ms(42));
        assert_eq!(s.percentile(99.0), ms(42));
    }

    #[test]
    fn slo_attainment_counts_boundary() {
        let mut s = LatencySeries::new();
        for v in [100u64, 200, 300, 400] {
            s.record(ms(v));
        }
        assert_eq!(s.slo_attainment(ms(250)), 0.5);
        assert_eq!(s.slo_attainment(ms(400)), 1.0);
        assert_eq!(s.slo_attainment(ms(50)), 0.0);
    }

    #[test]
    fn cdf_monotone() {
        let mut s = LatencySeries::new();
        let mut rng = crate::data::Rng::new(1);
        for _ in 0..500 {
            s.record(SimDuration::from_micros((rng.f64() * 1e6) as u64));
        }
        let cdf = s.cdf(20);
        assert_eq!(cdf.len(), 20);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_aggregate_components() {
        let mut m = Metrics::new();
        let mut l = LatencyLedger::new();
        l.charge(Component::EmbedGen, ms(100));
        l.charge(Component::Prefill, ms(50));
        let b = crate::simtime::Breakdown::from_ledger(&l);
        m.record_query(&b, ms(100), ms(150));
        m.record_query(&b, ms(100), ms(150));
        assert_eq!(m.queries(), 2);
        assert_eq!(m.component_total(Component::EmbedGen), ms(200));
        assert_eq!(m.component_mean(Component::Prefill), ms(50));
        m.bump("cache_hits", 3);
        assert_eq!(m.counter("cache_hits"), 3);
        assert_eq!(m.counter("nope"), 0);
    }
}
