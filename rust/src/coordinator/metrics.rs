//! Serving metrics: latency recording, percentiles, per-component
//! breakdowns, SLO attainment. The figure benches read these; the server
//! exposes them on its stats endpoint.
//!
//! ## Concurrency
//!
//! [`Metrics`] records through `&self` so the serving engine's worker
//! pool never serializes on metrics: latency samples go to sharded
//! mutex-striped buffers (a recorder touches one shard, picked by thread
//! id, for a few nanoseconds), component sums and event counters are
//! plain atomics. Reads take consistent *snapshots* ([`LatencySeries`])
//! and compute percentiles without mutating anything, so the stats
//! endpoint can be served from a shared reference.
//!
//! ## Bounded retention
//!
//! Each percentile query sorts a copy of the retained samples —
//! O(n log n) per stats call — so retention is **capped**: every stripe
//! is a ring buffer of [`RING_CAPACITY`] samples ([`MAX_RETAINED`] =
//! `RING_CAPACITY × SHARDS` total). Long-running servers therefore
//! compute percentiles over a sliding window of the most recent
//! ~65k samples at a bounded cost, while [`Metrics::queries`] keeps
//! counting every sample ever recorded (component means divide by the
//! true totals, not the window).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::simtime::{Breakdown, Component, SimDuration};

/// A latency series snapshot with exact percentile queries (we keep raw
/// samples — workloads are ≤ thousands of queries, exactness beats
/// HDR-style bucketing at this scale). All queries take `&self`: sorting
/// happens on an internal copy, so snapshots can be shared freely.
#[derive(Debug, Clone, Default)]
pub struct LatencySeries {
    samples_ns: Vec<u64>,
    /// True when `samples_ns` is known-sorted (snapshots sort once at
    /// construction); percentile queries on a sorted series are O(1).
    sorted: bool,
}

impl LatencySeries {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a snapshot, sorting once so every subsequent percentile /
    /// cdf query borrows instead of re-sorting.
    pub fn from_nanos(mut samples_ns: Vec<u64>) -> Self {
        samples_ns.sort_unstable();
        LatencySeries {
            samples_ns,
            sorted: true,
        }
    }

    pub fn record(&mut self, d: SimDuration) {
        self.samples_ns.push(d.as_nanos());
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ns.is_empty()
    }

    fn sorted(&self) -> std::borrow::Cow<'_, [u64]> {
        if self.sorted {
            std::borrow::Cow::Borrowed(&self.samples_ns)
        } else {
            let mut v = self.samples_ns.clone();
            v.sort_unstable();
            std::borrow::Cow::Owned(v)
        }
    }

    /// Exact percentile (nearest-rank), `p` in [0, 100]. Non-mutating:
    /// safe on a shared snapshot.
    pub fn percentile(&self, p: f64) -> SimDuration {
        if self.samples_ns.is_empty() {
            return SimDuration::ZERO;
        }
        let sorted = self.sorted();
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        SimDuration::from_nanos(sorted[rank.min(n) - 1])
    }

    pub fn median(&self) -> SimDuration {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> SimDuration {
        if self.samples_ns.is_empty() {
            return SimDuration::ZERO;
        }
        let sum: u128 = self.samples_ns.iter().map(|&x| x as u128).sum();
        SimDuration::from_nanos((sum / self.samples_ns.len() as u128) as u64)
    }

    pub fn max(&self) -> SimDuration {
        SimDuration::from_nanos(self.samples_ns.iter().copied().max().unwrap_or(0))
    }

    /// Fraction of samples at or below `slo`.
    pub fn slo_attainment(&self, slo: SimDuration) -> f64 {
        if self.samples_ns.is_empty() {
            return 1.0;
        }
        let ok = self
            .samples_ns
            .iter()
            .filter(|&&s| s <= slo.as_nanos())
            .count();
        ok as f64 / self.samples_ns.len() as f64
    }

    /// CDF points (latency, cumulative fraction) — Fig. 12's distribution.
    pub fn cdf(&self, points: usize) -> Vec<(SimDuration, f64)> {
        if self.samples_ns.is_empty() {
            return Vec::new();
        }
        let sorted = self.sorted();
        let n = sorted.len();
        (1..=points)
            .map(|i| {
                let frac = i as f64 / points as f64;
                let idx = ((frac * n as f64).ceil() as usize).min(n) - 1;
                (SimDuration::from_nanos(sorted[idx]), frac)
            })
            .collect()
    }
}

/// Per-stripe ring capacity. Bounds both memory and the O(n log n)
/// sort a percentile snapshot pays: at most [`MAX_RETAINED`] samples are
/// ever retained, with the oldest overwritten first.
pub const RING_CAPACITY: usize = 8_192;

/// Total retained-sample cap across all stripes (the percentile window).
pub const MAX_RETAINED: usize = RING_CAPACITY * SHARDS;

/// Fixed-capacity overwrite-oldest sample buffer (one stripe). The
/// recorded-total lives *inside* the same mutex as the buffer, so
/// `record` vs `clear` races can never desync counts from contents.
#[derive(Debug, Default)]
struct Ring {
    buf: Vec<u64>,
    /// Next overwrite position once `buf` reaches capacity.
    next: usize,
    /// Samples recorded into this stripe since the last clear
    /// (monotone; unaffected by overwrites).
    recorded: u64,
}

impl Ring {
    fn push(&mut self, v: u64) {
        self.recorded += 1;
        if self.buf.len() < RING_CAPACITY {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % RING_CAPACITY;
        }
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.recorded = 0;
    }
}

/// Mutex-striped sample sink: `record` locks one stripe briefly, keyed by
/// the calling thread, so concurrent recorders rarely contend. Each
/// stripe retains at most [`RING_CAPACITY`] samples (oldest overwritten);
/// `len` counts every record made since the last `clear`, derived from
/// the stripes themselves (no separate counter), so `len`, reads and
/// `clear` can never desync even when they race concurrent recorders.
#[derive(Debug)]
struct ShardedSeries {
    shards: Vec<Mutex<Ring>>,
}

const SHARDS: usize = 8;

impl ShardedSeries {
    fn new() -> Self {
        ShardedSeries {
            shards: (0..SHARDS).map(|_| Mutex::new(Ring::default())).collect(),
        }
    }

    fn shard_index() -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        (h.finish() as usize) % SHARDS
    }

    fn record(&self, ns: u64) {
        self.shards[Self::shard_index()].lock().unwrap().push(ns);
    }

    /// Samples recorded since the last clear (may exceed the retained
    /// window once rings wrap).
    fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().recorded as usize)
            .sum()
    }

    /// Snapshot of the *retained* window (≤ [`MAX_RETAINED`] most recent
    /// samples).
    fn snapshot(&self) -> LatencySeries {
        let mut all = Vec::new();
        for s in &self.shards {
            all.extend_from_slice(&s.lock().unwrap().buf);
        }
        LatencySeries::from_nanos(all)
    }

    fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

const ALL_LEN: usize = Component::ALL.len();

/// Full per-run metrics: TTFT + retrieval series, component sums, event
/// counters. Recording is `&self` (lock-free or shard-striped) so the
/// whole struct can live behind a shared reference in the serving engine.
#[derive(Debug)]
pub struct Metrics {
    retrieval: ShardedSeries,
    ttft: ShardedSeries,
    component_ns: [AtomicU64; ALL_LEN],
    counters: RwLock<HashMap<&'static str, AtomicU64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            retrieval: ShardedSeries::new(),
            ttft: ShardedSeries::new(),
            component_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            counters: RwLock::new(HashMap::new()),
        }
    }

    pub fn record_query(&self, breakdown: &Breakdown, retrieval: SimDuration, ttft: SimDuration) {
        self.retrieval.record(retrieval.as_nanos());
        self.ttft.record(ttft.as_nanos());
        for (i, c) in Component::ALL.iter().enumerate() {
            let ns = breakdown.get(*c).as_nanos();
            if ns > 0 {
                self.component_ns[i].fetch_add(ns, Ordering::Relaxed);
            }
        }
    }

    pub fn bump(&self, counter: &'static str, by: u64) {
        {
            let map = self.counters.read().unwrap();
            if let Some(a) = map.get(counter) {
                a.fetch_add(by, Ordering::Relaxed);
                return;
            }
        }
        let mut map = self.counters.write().unwrap();
        map.entry(counter)
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, counter: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(counter)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Snapshot of the retrieval-latency series (the retained window of
    /// at most [`MAX_RETAINED`] most recent samples).
    pub fn retrieval(&self) -> LatencySeries {
        self.retrieval.snapshot()
    }

    /// Snapshot of the TTFT series (same retention window).
    pub fn ttft(&self) -> LatencySeries {
        self.ttft.snapshot()
    }

    pub fn component_total(&self, c: Component) -> SimDuration {
        let idx = Component::ALL.iter().position(|x| *x == c).unwrap();
        SimDuration::from_nanos(self.component_ns[idx].load(Ordering::Relaxed))
    }

    /// Mean per-query time in component `c`.
    pub fn component_mean(&self, c: Component) -> SimDuration {
        let n = self.retrieval.len().max(1) as u64;
        SimDuration::from_nanos(self.component_total(c).as_nanos() / n)
    }

    pub fn queries(&self) -> usize {
        self.retrieval.len()
    }

    /// Drop all recorded samples/counters (post-warmup reset). `&self` so
    /// a shared engine can reset between measurement phases.
    pub fn reset(&self) {
        self.retrieval.clear();
        self.ttft.clear();
        for a in &self.component_ns {
            a.store(0, Ordering::Relaxed);
        }
        self.counters.write().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::LatencyLedger;

    fn ms(v: u64) -> SimDuration {
        SimDuration::from_millis(v)
    }

    #[test]
    fn percentiles_exact() {
        let mut s = LatencySeries::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            s.record(ms(v));
        }
        assert_eq!(s.median(), ms(50));
        assert_eq!(s.percentile(95.0), ms(100));
        assert_eq!(s.percentile(10.0), ms(10));
        assert_eq!(s.max(), ms(100));
        assert_eq!(s.mean(), ms(55));
    }

    #[test]
    fn percentile_of_singleton() {
        let mut s = LatencySeries::new();
        s.record(ms(42));
        assert_eq!(s.median(), ms(42));
        assert_eq!(s.percentile(99.0), ms(42));
    }

    #[test]
    fn percentile_does_not_mutate() {
        // The stats endpoint serves from a shared reference: queries must
        // leave the snapshot untouched (insertion order preserved).
        let mut s = LatencySeries::new();
        for v in [50u64, 10, 30] {
            s.record(ms(v));
        }
        let shared = &s;
        assert_eq!(shared.median(), ms(30));
        assert_eq!(shared.percentile(100.0), ms(50));
        assert_eq!(shared.samples_ns, vec![ms(50).as_nanos(), ms(10).as_nanos(), ms(30).as_nanos()]);
    }

    #[test]
    fn slo_attainment_counts_boundary() {
        let mut s = LatencySeries::new();
        for v in [100u64, 200, 300, 400] {
            s.record(ms(v));
        }
        assert_eq!(s.slo_attainment(ms(250)), 0.5);
        assert_eq!(s.slo_attainment(ms(400)), 1.0);
        assert_eq!(s.slo_attainment(ms(50)), 0.0);
    }

    #[test]
    fn cdf_monotone() {
        let mut s = LatencySeries::new();
        let mut rng = crate::data::Rng::new(1);
        for _ in 0..500 {
            s.record(SimDuration::from_micros((rng.f64() * 1e6) as u64));
        }
        let cdf = s.cdf(20);
        assert_eq!(cdf.len(), 20);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_aggregate_components() {
        let m = Metrics::new();
        let mut l = LatencyLedger::new();
        l.charge(Component::EmbedGen, ms(100));
        l.charge(Component::Prefill, ms(50));
        let b = crate::simtime::Breakdown::from_ledger(&l);
        m.record_query(&b, ms(100), ms(150));
        m.record_query(&b, ms(100), ms(150));
        assert_eq!(m.queries(), 2);
        assert_eq!(m.component_total(Component::EmbedGen), ms(200));
        assert_eq!(m.component_mean(Component::Prefill), ms(50));
        m.bump("cache_hits", 3);
        assert_eq!(m.counter("cache_hits"), 3);
        assert_eq!(m.counter("nope"), 0);
    }

    #[test]
    fn ring_caps_retained_samples() {
        // Single-threaded: every sample lands in one stripe; past
        // capacity the oldest are overwritten while totals keep counting.
        let m = Metrics::new();
        let b = Breakdown::default();
        let n = RING_CAPACITY + 100;
        for i in 0..n {
            m.record_query(&b, SimDuration::from_nanos(i as u64 + 1), ms(1));
        }
        assert_eq!(m.queries(), n, "totals count every record");
        let snap = m.retrieval();
        assert_eq!(snap.len(), RING_CAPACITY, "retention capped at the ring");
        // Newest sample retained; the 100 oldest overwritten.
        assert_eq!(snap.max(), SimDuration::from_nanos(n as u64));
        assert!(snap.percentile(0.0) > SimDuration::from_nanos(100));
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let m = &m;
                s.spawn(move || {
                    let b = Breakdown::default();
                    for i in 0..250u64 {
                        m.record_query(&b, ms(t * 250 + i), ms(1));
                        m.bump("ops", 1);
                    }
                });
            }
        });
        assert_eq!(m.queries(), 2000);
        assert_eq!(m.counter("ops"), 2000);
        let snap = m.retrieval();
        assert_eq!(snap.len(), 2000);
        // Every thread's max sample must be present in the merged snapshot.
        assert_eq!(snap.max(), ms(7 * 250 + 249));
        m.reset();
        assert_eq!(m.queries(), 0);
        assert_eq!(m.counter("ops"), 0);
    }
}
