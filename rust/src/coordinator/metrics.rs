//! Serving metrics: latency recording, percentiles, per-component
//! breakdowns, SLO attainment. The figure benches read these; the server
//! exposes them on its stats endpoint.
//!
//! ## Concurrency
//!
//! [`Metrics`] records through `&self` so the serving engine's worker
//! pool never serializes on metrics: latency samples go to sharded
//! mutex-striped buffers (a recorder touches one shard, picked by thread
//! id, for a few nanoseconds), component sums and event counters are
//! plain atomics. Reads take consistent *snapshots* ([`LatencySeries`])
//! and compute percentiles without mutating anything, so the stats
//! endpoint can be served from a shared reference.
//!
//! ## Streaming quantiles
//!
//! [`LatencySeries`] is a fixed-bin **log histogram** (HDR-style:
//! [`SUB_BUCKETS`] sub-buckets per power of two, ≤ 1/32 ≈ 3.1% relative
//! bin width), not a sample buffer. Recording is O(1), a percentile read
//! walks the ~[`NUM_BINS`] bins — no sort, no copy — and memory is a few
//! KiB regardless of how many samples a long-running server records.
//! Count, sum (→ mean) and max are tracked **exactly** alongside the
//! bins; percentiles are exact for values below [`SUB_BUCKETS`] ns and
//! land on a deterministic bin upper bound above it (capped at the exact
//! max), so tests can assert exact equality via
//! [`LatencySeries::bin_value`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::simtime::{Breakdown, Component, SimDuration};

/// Sub-buckets per power-of-two octave (2^[`SUB_BITS`]). Bounds the
/// relative quantization error of a percentile at 1/32 ≈ 3.1%.
pub const SUB_BUCKETS: u64 = 1 << SUB_BITS;
const SUB_BITS: u32 = 5;
/// Total bins needed to cover the full u64 nanosecond range: the two
/// exact leading octaves (indices 0..64 cover values 0..64 one-to-one)
/// plus 32 log-spaced bins for each of the remaining 58 octaves.
pub const NUM_BINS: usize = ((64 - SUB_BITS as usize) + 1) * SUB_BUCKETS as usize;

/// Bin index for a nanosecond value. Values below `2 × SUB_BUCKETS` map
/// one-to-one (exact); above that, each octave splits into
/// [`SUB_BUCKETS`] equal-width bins.
fn bin_index(ns: u64) -> usize {
    if ns < 2 * SUB_BUCKETS {
        return ns as usize;
    }
    let h = 63 - ns.leading_zeros(); // 2^h <= ns, h >= SUB_BITS + 1
    let shift = h - SUB_BITS;
    (((shift + 1) as usize) << SUB_BITS) + ((ns >> shift) & (SUB_BUCKETS - 1)) as usize
}

/// Upper bound (inclusive) of a bin — the deterministic value a
/// percentile query reports for samples in that bin.
fn bin_upper(index: usize) -> u64 {
    if index < 2 * SUB_BUCKETS as usize {
        return index as u64;
    }
    let shift = (index >> SUB_BITS) as u32 - 1;
    let sub = index as u64 & (SUB_BUCKETS - 1);
    let lower = (SUB_BUCKETS + sub) << shift;
    lower + ((1u64 << shift) - 1)
}

/// A latency series as a streaming quantile sketch: a fixed-bin log
/// histogram plus exact count/sum/max. All queries take `&self` and do
/// no allocation or sorting, so snapshots can be shared freely and the
/// stats endpoint stays O([`NUM_BINS`]) under any load.
#[derive(Debug, Clone, Default)]
pub struct LatencySeries {
    /// Sample counts per log bin; allocated to [`NUM_BINS`] on first
    /// record (an empty series carries no storage).
    bins: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

impl LatencySeries {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a series from raw samples (bench/test helper).
    pub fn from_nanos(samples_ns: Vec<u64>) -> Self {
        let mut s = Self::new();
        for ns in samples_ns {
            s.record(SimDuration::from_nanos(ns));
        }
        s
    }

    /// The deterministic value [`percentile`](Self::percentile) reports
    /// for any sample that fell in `d`'s bin (its inclusive upper
    /// bound). Exact-match assertions in tests anchor on this instead of
    /// hard-coding bin arithmetic.
    pub fn bin_value(d: SimDuration) -> SimDuration {
        SimDuration::from_nanos(bin_upper(bin_index(d.as_nanos())))
    }

    pub fn record(&mut self, d: SimDuration) {
        let ns = d.as_nanos();
        if self.bins.is_empty() {
            self.bins = vec![0; NUM_BINS];
        }
        self.bins[bin_index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Merge another series into this one (bin-wise; count/sum/max stay
    /// exact). Used to splice the per-thread stripes into one snapshot.
    pub fn merge(&mut self, other: &LatencySeries) {
        if other.count == 0 {
            return;
        }
        if self.bins.is_empty() {
            self.bins = vec![0; NUM_BINS];
        }
        for (b, o) in self.bins.iter_mut().zip(&other.bins) {
            *b += o;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Nanosecond value at `rank` (1-based, nearest-rank): the upper
    /// bound of the bin holding the rank-th smallest sample, capped at
    /// the exact max so the top of the distribution never over-reports.
    fn value_at_rank(&self, rank: u64) -> u64 {
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bin_upper(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Percentile (nearest-rank over the histogram bins), `p` in
    /// [0, 100]. Deterministic: the reported value is always a bin upper
    /// bound ([`LatencySeries::bin_value`]) capped at the exact max —
    /// within 3.1% of the exact sample, and bit-equal across runs that
    /// record the same multiset of samples in any order.
    pub fn percentile(&self, p: f64) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        SimDuration::from_nanos(self.value_at_rank(rank.min(self.count)))
    }

    pub fn median(&self) -> SimDuration {
        self.percentile(50.0)
    }

    /// Exact mean (sum and count are tracked outside the bins).
    pub fn mean(&self) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        SimDuration::from_nanos((self.sum_ns / self.count as u128) as u64)
    }

    /// Exact maximum.
    pub fn max(&self) -> SimDuration {
        SimDuration::from_nanos(self.max_ns)
    }

    /// Fraction of samples in bins at or below `slo`'s bin. Boundary
    /// semantics are bin-deterministic: a sample counts as attained iff
    /// its bin index ≤ the SLO's bin index (samples equal to the SLO
    /// always count; samples in the same bin but above it do too — the
    /// ≤3.1% quantization the sketch trades for O(1) recording).
    pub fn slo_attainment(&self, slo: SimDuration) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        let cut = bin_index(slo.as_nanos());
        let ok: u64 = self.bins.iter().take(cut + 1).sum();
        ok as f64 / self.count as f64
    }

    /// Exact sum of all recorded samples in nanoseconds (tracked outside
    /// the bins, like count and max).
    pub fn sum_nanos(&self) -> u128 {
        self.sum_ns
    }

    /// Cumulative histogram rows for Prometheus exposition: one
    /// `(upper_bound_ns, cumulative_count)` pair per **occupied** bin, in
    /// ascending bound order. Emitting only occupied bins keeps the
    /// exposition bounded by the number of distinct latency bins actually
    /// hit instead of all [`NUM_BINS`]; cumulative counts make the rows
    /// valid `le` bucket values as-is.
    pub fn prom_buckets(&self) -> Vec<(u64, u64)> {
        let mut rows = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c > 0 {
                cum += c;
                rows.push((bin_upper(i), cum));
            }
        }
        rows
    }

    /// CDF points (latency, cumulative fraction) — Fig. 12's distribution.
    pub fn cdf(&self, points: usize) -> Vec<(SimDuration, f64)> {
        if self.count == 0 {
            return Vec::new();
        }
        (1..=points)
            .map(|i| {
                let frac = i as f64 / points as f64;
                let rank = ((frac * self.count as f64).ceil() as u64).min(self.count).max(1);
                (SimDuration::from_nanos(self.value_at_rank(rank)), frac)
            })
            .collect()
    }
}

/// Mutex-striped sample sink: `record` locks one stripe briefly, keyed by
/// the calling thread, so concurrent recorders rarely contend. Each
/// stripe is a [`LatencySeries`] histogram — O(1) per record, a few KiB
/// per stripe, **no** retention window: every sample since the last
/// `clear` is represented, at bounded memory, however long the server
/// runs. `len` is derived from the stripes themselves (no separate
/// counter), so `len`, reads and `clear` can never desync even when they
/// race concurrent recorders.
#[derive(Debug)]
struct ShardedSeries {
    shards: Vec<Mutex<LatencySeries>>,
}

const SHARDS: usize = 8;

impl ShardedSeries {
    fn new() -> Self {
        ShardedSeries {
            shards: (0..SHARDS)
                .map(|_| Mutex::new(LatencySeries::new()))
                .collect(),
        }
    }

    fn shard_index() -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        (h.finish() as usize) % SHARDS
    }

    fn record(&self, ns: u64) {
        self.shards[Self::shard_index()]
            .lock()
            .unwrap()
            .record(SimDuration::from_nanos(ns));
    }

    /// Samples recorded since the last clear.
    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Merged snapshot of every stripe (all samples since the last
    /// clear — histograms merge losslessly, so there is no window).
    fn snapshot(&self) -> LatencySeries {
        let mut all = LatencySeries::new();
        for s in &self.shards {
            all.merge(&s.lock().unwrap());
        }
        all
    }

    fn clear(&self) {
        for s in &self.shards {
            *s.lock().unwrap() = LatencySeries::new();
        }
    }
}

const ALL_LEN: usize = Component::ALL.len();

/// Full per-run metrics: TTFT + retrieval series, component sums, event
/// counters. Recording is `&self` (lock-free or shard-striped) so the
/// whole struct can live behind a shared reference in the serving engine.
#[derive(Debug)]
pub struct Metrics {
    retrieval: ShardedSeries,
    ttft: ShardedSeries,
    component_ns: [AtomicU64; ALL_LEN],
    counters: RwLock<HashMap<&'static str, AtomicU64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            retrieval: ShardedSeries::new(),
            ttft: ShardedSeries::new(),
            component_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            counters: RwLock::new(HashMap::new()),
        }
    }

    pub fn record_query(&self, breakdown: &Breakdown, retrieval: SimDuration, ttft: SimDuration) {
        self.retrieval.record(retrieval.as_nanos());
        self.ttft.record(ttft.as_nanos());
        for (i, c) in Component::ALL.iter().enumerate() {
            let ns = breakdown.get(*c).as_nanos();
            if ns > 0 {
                self.component_ns[i].fetch_add(ns, Ordering::Relaxed);
            }
        }
    }

    pub fn bump(&self, counter: &'static str, by: u64) {
        {
            let map = self.counters.read().unwrap();
            if let Some(a) = map.get(counter) {
                a.fetch_add(by, Ordering::Relaxed);
                return;
            }
        }
        let mut map = self.counters.write().unwrap();
        map.entry(counter)
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, counter: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(counter)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Every named counter with its current value, sorted by name — the
    /// metrics exposition iterates this instead of knowing the names.
    pub fn counters_snapshot(&self) -> Vec<(&'static str, u64)> {
        let map = self.counters.read().unwrap();
        let mut rows: Vec<(&'static str, u64)> = map
            .iter()
            .map(|(&k, v)| (k, v.load(Ordering::Relaxed)))
            .collect();
        rows.sort_unstable_by_key(|&(k, _)| k);
        rows
    }

    /// Snapshot of the retrieval-latency series (merged across stripes;
    /// covers every sample since the last reset — no retention window).
    pub fn retrieval(&self) -> LatencySeries {
        self.retrieval.snapshot()
    }

    /// Snapshot of the TTFT series (same coverage).
    pub fn ttft(&self) -> LatencySeries {
        self.ttft.snapshot()
    }

    pub fn component_total(&self, c: Component) -> SimDuration {
        // Direct discriminant indexing — `Component::index` equals the
        // position in `ALL` (pinned by a simtime unit test).
        SimDuration::from_nanos(self.component_ns[c.index()].load(Ordering::Relaxed))
    }

    /// Mean per-query time in component `c`.
    pub fn component_mean(&self, c: Component) -> SimDuration {
        let n = self.retrieval.len().max(1) as u64;
        SimDuration::from_nanos(self.component_total(c).as_nanos() / n)
    }

    pub fn queries(&self) -> usize {
        self.retrieval.len()
    }

    /// Drop all recorded samples/counters (post-warmup reset). `&self` so
    /// a shared engine can reset between measurement phases.
    pub fn reset(&self) {
        self.retrieval.clear();
        self.ttft.clear();
        for a in &self.component_ns {
            a.store(0, Ordering::Relaxed);
        }
        self.counters.write().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::LatencyLedger;

    fn ms(v: u64) -> SimDuration {
        SimDuration::from_millis(v)
    }

    #[test]
    fn percentiles_exact() {
        let mut s = LatencySeries::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            s.record(ms(v));
        }
        // Interior percentiles land on the deterministic bin upper bound
        // of the exact sample (≤3.1% away, bit-stable across runs)...
        assert_eq!(s.median(), LatencySeries::bin_value(ms(50)));
        assert_eq!(s.percentile(10.0), LatencySeries::bin_value(ms(10)));
        // ...while the top of the distribution, max and mean stay exact.
        assert_eq!(s.percentile(95.0), ms(100));
        assert_eq!(s.max(), ms(100));
        assert_eq!(s.mean(), ms(55));
    }

    #[test]
    fn percentile_of_singleton() {
        let mut s = LatencySeries::new();
        s.record(ms(42));
        assert_eq!(s.median(), ms(42));
        assert_eq!(s.percentile(99.0), ms(42));
    }

    #[test]
    fn percentile_does_not_mutate() {
        // The stats endpoint serves from a shared reference: queries must
        // be `&self`, repeatable, and order-insensitive.
        let mut s = LatencySeries::new();
        for v in [50u64, 10, 30] {
            s.record(ms(v));
        }
        let shared = &s;
        assert_eq!(shared.median(), LatencySeries::bin_value(ms(30)));
        assert_eq!(shared.percentile(100.0), ms(50));
        // Repeating the queries yields identical answers.
        assert_eq!(shared.median(), LatencySeries::bin_value(ms(30)));
        assert_eq!(shared.percentile(100.0), ms(50));
        // Recording in a different order produces a bit-identical series.
        let reordered = LatencySeries::from_nanos(vec![
            ms(10).as_nanos(),
            ms(30).as_nanos(),
            ms(50).as_nanos(),
        ]);
        assert_eq!(reordered.median(), shared.median());
        assert_eq!(reordered.percentile(100.0), shared.percentile(100.0));
    }

    #[test]
    fn slo_attainment_counts_boundary() {
        let mut s = LatencySeries::new();
        for v in [100u64, 200, 300, 400] {
            s.record(ms(v));
        }
        assert_eq!(s.slo_attainment(ms(250)), 0.5);
        assert_eq!(s.slo_attainment(ms(400)), 1.0);
        assert_eq!(s.slo_attainment(ms(50)), 0.0);
    }

    #[test]
    fn cdf_monotone() {
        let mut s = LatencySeries::new();
        let mut rng = crate::data::Rng::new(1);
        for _ in 0..500 {
            s.record(SimDuration::from_micros((rng.f64() * 1e6) as u64));
        }
        let cdf = s.cdf(20);
        assert_eq!(cdf.len(), 20);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_matches_sorted_oracle_within_documented_error() {
        // Property test for the ≤1/32 ≈ 3.1% relative quantile error
        // claim: compare percentile/cdf/slo_attainment against an exact
        // sorted nearest-rank oracle over random log-uniform samples, and
        // assert count/mean/max are exact.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0x81ED));
        for &n in &[1usize, 7, 100, 2_500] {
            // Log-uniform over ~1ns..100s so every octave regime of the
            // sketch (exact bins, sub-bucketed octaves) gets exercised.
            let samples: Vec<u64> = (0..n)
                .map(|_| (10f64.powf(rng.f64() * 11.0).max(1.0)) as u64)
                .collect();
            let s = LatencySeries::from_nanos(samples.clone());
            let mut sorted = samples.clone();
            sorted.sort_unstable();

            // Exact side-channels.
            assert_eq!(s.len(), n);
            assert_eq!(s.max().as_nanos(), *sorted.last().unwrap());
            let exact_sum: u128 = samples.iter().map(|&v| v as u128).sum();
            assert_eq!(s.sum_nanos(), exact_sum);
            assert_eq!(s.mean().as_nanos(), (exact_sum / n as u128) as u64);

            // Percentiles: the sketch reports the bin upper bound of the
            // exact nearest-rank sample, capped at the exact max — never
            // below the exact value, never more than 1/32 above it.
            for p in [0.0, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
                let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
                let exact = sorted[rank.min(n) - 1];
                let approx = s.percentile(p).as_nanos();
                assert!(approx >= exact, "p{p} n={n}: {approx} < exact {exact}");
                assert!(
                    (approx - exact) as f64 <= exact as f64 / 32.0,
                    "p{p} n={n}: {approx} vs exact {exact} exceeds 1/32"
                );
            }

            // CDF: same bound at every point, fractions exact.
            for (i, &(v, frac)) in s.cdf(10).iter().enumerate() {
                assert!((frac - (i + 1) as f64 / 10.0).abs() < 1e-12);
                let rank = ((frac * n as f64).ceil() as usize).clamp(1, n);
                let exact = sorted[rank - 1];
                let approx = v.as_nanos();
                assert!(approx >= exact);
                assert!((approx - exact) as f64 <= exact as f64 / 32.0);
            }

            // SLO attainment: bin-deterministic semantics — exactly the
            // fraction of samples whose bin is at or below the SLO's bin,
            // which can only over-count the exact ≤-fraction (by samples
            // sharing the SLO's bin) and never under-count it.
            for &slo in sorted.iter().step_by((n / 5).max(1)) {
                let got = s.slo_attainment(SimDuration::from_nanos(slo));
                let cut = bin_index(slo);
                let by_bin =
                    samples.iter().filter(|&&v| bin_index(v) <= cut).count() as f64 / n as f64;
                let exact_le = samples.iter().filter(|&&v| v <= slo).count() as f64 / n as f64;
                assert!((got - by_bin).abs() < 1e-12, "slo={slo} n={n}");
                assert!(got >= exact_le - 1e-12, "slo={slo} n={n}");
            }
        }
    }

    #[test]
    fn metrics_aggregate_components() {
        let m = Metrics::new();
        let mut l = LatencyLedger::new();
        l.charge(Component::EmbedGen, ms(100));
        l.charge(Component::Prefill, ms(50));
        let b = crate::simtime::Breakdown::from_ledger(&l);
        m.record_query(&b, ms(100), ms(150));
        m.record_query(&b, ms(100), ms(150));
        assert_eq!(m.queries(), 2);
        assert_eq!(m.component_total(Component::EmbedGen), ms(200));
        assert_eq!(m.component_mean(Component::Prefill), ms(50));
        m.bump("cache_hits", 3);
        assert_eq!(m.counter("cache_hits"), 3);
        assert_eq!(m.counter("nope"), 0);
    }

    #[test]
    fn histogram_retains_all_samples_in_bounded_memory() {
        // No retention window: a sample count far beyond the old ring
        // capacity is fully represented — snapshot len, max and the
        // bottom of the distribution all see every record.
        let m = Metrics::new();
        let b = Breakdown::default();
        let n = 100_000usize;
        for i in 0..n {
            m.record_query(&b, SimDuration::from_nanos(i as u64 + 1), ms(1));
        }
        assert_eq!(m.queries(), n, "totals count every record");
        let snap = m.retrieval();
        assert_eq!(snap.len(), n, "snapshot covers every sample");
        assert_eq!(snap.max(), SimDuration::from_nanos(n as u64));
        // The smallest sample (1 ns, below the exact-bin cutoff) is
        // still present and reported exactly.
        assert_eq!(snap.percentile(0.0), SimDuration::from_nanos(1));
        // The sketch itself stays a fixed-size array of bins.
        assert_eq!(snap.bins.len(), NUM_BINS);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let m = &m;
                s.spawn(move || {
                    let b = Breakdown::default();
                    for i in 0..250u64 {
                        m.record_query(&b, ms(t * 250 + i), ms(1));
                        m.bump("ops", 1);
                    }
                });
            }
        });
        assert_eq!(m.queries(), 2000);
        assert_eq!(m.counter("ops"), 2000);
        let snap = m.retrieval();
        assert_eq!(snap.len(), 2000);
        // Every thread's max sample must be present in the merged snapshot.
        assert_eq!(snap.max(), ms(7 * 250 + 249));
        m.reset();
        assert_eq!(m.queries(), 0);
        assert_eq!(m.counter("ops"), 0);
    }
}
