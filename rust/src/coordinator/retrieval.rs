//! The serving engine (paper Fig. 9 + the prefill tail of Fig. 6):
//! query embedding → index search → chunk fetch → prompt assembly →
//! prefill. Produces the TTFT breakdown every figure is built from.
//!
//! ## Engine split
//!
//! [`Engine`] is the shared, immutable serving core: embedder, LLM, text
//! store and metrics are all internally synchronized, and the index sits
//! behind an `RwLock` whose read side is taken only for the (now
//! `&self`) `VectorIndex::search` and `commit` calls. `handle` therefore
//! takes `&self` — N worker threads drive N queries through one `Engine`
//! concurrently. All per-query state lives on the calling thread's stack
//! ([`QueryOutcome`] et al.), never in the engine.
//!
//! Online mutations go through [`Engine::insert`] / [`Engine::remove`].
//! On an index that supports concurrent updates (the sharded
//! [`crate::index::ShardedEdgeIndex`]) those take the engine's *read*
//! lease plus only the owning shard's write lease, so a query and an
//! insert to different shards overlap; on a single
//! [`crate::index::EdgeIndex`] they fall back to the exclusive engine
//! write lease ([`Engine::index_mut`]), draining in-flight searches
//! first. The lock hierarchy is documented in `docs/ARCHITECTURE.md`.

use std::sync::{Arc, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

use anyhow::Result;

use crate::config::DeviceProfile;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::texts::TextStore;
use crate::embedding::Embedder;
use crate::index::{ProbeTable, SearchEvents, VectorIndex};
use crate::llm::Llm;
use crate::simtime::{Breakdown, Component, LatencyLedger, SimDuration};
use crate::trace::{self, TagValue};

/// One served query's full outcome.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    /// (chunk id, score), descending.
    pub hits: Vec<(u32, f32)>,
    /// Modeled retrieval latency (vector search side of TTFT).
    pub retrieval: SimDuration,
    /// Modeled time-to-first-token (retrieval + prefill + reloads).
    pub ttft: SimDuration,
    pub breakdown: Breakdown,
    pub events: SearchEvents,
    pub prompt_tokens: usize,
    /// Predicted first token (real prefill only).
    pub first_token: Option<i32>,
    /// Wall-clock coordinator time actually spent (L3 perf accounting).
    pub wall: std::time::Duration,
}

/// The shared serving engine: owns one index configuration plus the
/// shared LLM. `handle` is `&self` — wrap in an `Arc` and serve from as
/// many threads as you like.
pub struct Engine {
    index: RwLock<Box<dyn VectorIndex>>,
    embedder: Embedder,
    llm: Llm,
    device: DeviceProfile,
    chunk_texts: TextStore,
    top_k: usize,
    real_prefill: bool,
    metrics: Metrics,
    /// The scheduler's fused embed stage, wired (once) by
    /// [`crate::sched::BatchScheduler::new`]: with it set,
    /// [`Engine::insert`] embeds through the same cross-query batching
    /// path served queries use instead of calling the embedder inline.
    embed_stage: OnceLock<Arc<crate::sched::EmbedBatcher>>,
}

/// Former name of [`Engine`], kept so existing call sites and docs keep
/// working; the pipeline *is* the engine now.
pub type RagPipeline = Engine;

impl Engine {
    pub fn new(
        index: Box<dyn VectorIndex>,
        embedder: Embedder,
        llm: Llm,
        device: DeviceProfile,
        chunk_texts: TextStore,
        top_k: usize,
        real_prefill: bool,
    ) -> Self {
        Engine {
            index: RwLock::new(index),
            embedder,
            llm,
            device,
            chunk_texts,
            top_k,
            real_prefill,
            metrics: Metrics::new(),
            embed_stage: OnceLock::new(),
        }
    }

    /// Route this engine's insert-path embedding through a fused embed
    /// stage (called once by [`crate::sched::BatchScheduler::new`]), so
    /// served queries and online inserts take one embedding code path
    /// and fuse into the same kernel batches. Later calls are ignored.
    pub fn set_embed_stage(&self, stage: Arc<crate::sched::EmbedBatcher>) {
        let _ = self.embed_stage.set(stage);
    }

    /// Shared (read-leased) access to the index — concurrent with queries.
    pub fn index(&self) -> RwLockReadGuard<'_, Box<dyn VectorIndex>> {
        self.index.read().unwrap()
    }

    /// Exclusive (write-leased) access to the index: threshold pinning
    /// and other whole-index mutations. Blocks until in-flight searches
    /// drain. Prefer [`Engine::insert`] / [`Engine::remove`] for online
    /// updates — on a sharded index they stall only the owning shard.
    pub fn index_mut(&self) -> RwLockWriteGuard<'_, Box<dyn VectorIndex>> {
        self.index.write().unwrap()
    }

    /// Insert a chunk online (§5.4): embeds `text`, allocates its id from
    /// the shared text store, and routes it into the index. On an index
    /// supporting concurrent updates (the sharded
    /// [`crate::index::ShardedEdgeIndex`]) this runs under the engine's
    /// *read* lease and write-leases only the owning shard, so concurrent
    /// queries to other shards keep flowing; on a plain
    /// [`crate::index::EdgeIndex`] it takes the exclusive engine lease.
    /// Returns `(chunk id, global cluster id)`.
    ///
    /// The id is pushed to the text store *before* the index insert, so a
    /// concurrent query can never retrieve an id whose text is missing.
    pub fn insert(&self, text: &str) -> Result<(u32, u32)> {
        // Embed outside any lease: queries keep flowing while the
        // embedder works. With a scheduler in front, go through its
        // fused embed stage — bit-identical rows, but concurrent inserts
        // and queries coalesce into one kernel batch.
        let emb = match self.embed_stage.get() {
            Some(stage) => {
                let (r, info) = stage.embed_one_info(text);
                crate::sched::record_stage_spans("embed.wait", "embed.exec", &info);
                r?
            }
            None => {
                let t0 = trace::clock();
                let emb = self.embedder.embed_one(text)?;
                if let Some(t0) = t0 {
                    trace::record_since("embed.inline", t0, &[]);
                }
                emb
            }
        };
        // The index mutation (WAL append included — the WAL records its
        // own `wal.append`/`wal.rotate` sub-spans) under one span.
        let t0 = trace::clock();
        let applied = {
            let index = self.index.read().unwrap();
            if index.supports_concurrent_updates() {
                let id = self.chunk_texts.push(text.to_string());
                Some((id, index.insert_chunk_concurrent(id, text, &emb)?))
            } else {
                None
            }
        };
        let result = match applied {
            Some(done) => done,
            None => {
                let mut index = self.index.write().unwrap();
                let id = self.chunk_texts.push(text.to_string());
                let cluster = index.insert_chunk(id, text, &emb)?;
                (id, cluster)
            }
        };
        if let Some(t0) = t0 {
            trace::record_since("insert.apply", t0, &[("cluster", TagValue::U64(u64::from(result.1)))]);
        }
        Ok(result)
    }

    /// Remove a chunk online (§5.4). Shard-scoped on an index that
    /// supports concurrent updates (engine read lease + owning shard's
    /// write lease), exclusive otherwise. Returns false if the id is
    /// unknown.
    pub fn remove(&self, id: u32) -> Result<bool> {
        {
            let index = self.index.read().unwrap();
            if index.supports_concurrent_updates() {
                return index.remove_chunk_concurrent(id);
            }
        }
        self.index.write().unwrap().remove_chunk(id)
    }

    /// Run one online cross-shard rebalance round
    /// ([`crate::index::rebalance`]) under the engine's *read* lease —
    /// concurrent queries keep serving (bit-identically) throughout.
    /// Inert (all-zero report) on unsharded indexes.
    pub fn rebalance(&self) -> Result<crate::index::RebalanceReport> {
        self.index.read().unwrap().rebalance()
    }

    /// Change the live shard count to `target` (grow appends empty
    /// shards; shrink drains-then-retires) under the engine's *read*
    /// lease — concurrent queries keep serving, bit-identically, through
    /// every topology swap. Errors on unsharded indexes.
    pub fn reshard(&self, target: usize) -> Result<crate::index::ReshardReport> {
        self.index.read().unwrap().reshard(target)
    }

    /// Shared metrics — recording is internally synchronized.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared chunk-text store ([`Engine::insert`] appends to it).
    pub fn texts(&self) -> TextStore {
        self.chunk_texts.clone()
    }

    /// The query embedder (shared, thread-safe).
    pub fn embedder(&self) -> &Embedder {
        &self.embedder
    }

    /// Serve one query end to end. `&self`: any number of calls may run
    /// concurrently; the index read lock is held only for the search and
    /// the (brief) cache-commit, never across embedding or prefill.
    pub fn handle(&self, query_text: &str) -> Result<QueryOutcome> {
        let wall_start = Instant::now();
        let t0 = trace::clock();
        let q = self.embedder.embed_one(query_text)?;
        if let Some(t0) = t0 {
            trace::record_since("embed.inline", t0, &[]);
        }
        self.handle_prepared(query_text, &q, None, wall_start)
    }

    /// Serve a query whose embedding (and optionally centroid-probe
    /// scores against a [`ProbeTable`] snapshot) were computed upstream —
    /// the cross-query batch scheduler's ([`crate::sched`]) stage-3 entry
    /// point. Identical to [`Engine::handle`] in modeled costs, search
    /// results and cache commits: the modeled `QueryEmbed` charge depends
    /// only on the text, and the search runs
    /// [`VectorIndex::search_with_scores`], which reproduces
    /// [`VectorIndex::search`] exactly for scores taken from the current
    /// snapshot. `wall_start` lets the caller account queue/batch time
    /// into the reported coordinator wall time.
    pub fn handle_prepared(
        &self,
        query_text: &str,
        q: &[f32],
        probe: Option<(&ProbeTable, &[f32])>,
        wall_start: Instant,
    ) -> Result<QueryOutcome> {
        let mut ledger = LatencyLedger::new();

        // Query embedding (same embedding model as indexing — Fig. 1b
        // step 1). Charged at the device's generation rate regardless of
        // which path computed the vector.
        ledger.charge(
            Component::QueryEmbed,
            self.device.embed_gen_cost(query_text.len() as u64),
        );

        // Vector search through the configured index (shared read lease).
        let t_search = trace::clock();
        let search = {
            let index = self.index.read().unwrap();
            match probe {
                Some((table, scores)) => index.search_with_scores(q, table, scores, self.top_k)?,
                None => index.search(q, self.top_k)?,
            }
        };
        ledger.merge(&search.ledger);
        if let Some(t0) = t_search {
            trace::record_since("search", t0, &[]);
            // Per-shard walks ran on pool worker threads (no thread-local
            // trace there); their timings came back by value — attribute
            // them here, on the query's own thread.
            for w in &search.shard_walks {
                trace::record(
                    "shard.walk",
                    w.walk_ns,
                    &[
                        ("shard", TagValue::U64(u64::from(w.shard))),
                        ("clusters", TagValue::U64(u64::from(w.clusters))),
                        ("generated", TagValue::U64(u64::from(w.generated))),
                        ("loaded", TagValue::U64(u64::from(w.loaded))),
                        ("cache_hits", TagValue::U64(u64::from(w.cache_hits))),
                    ],
                );
            }
            trace::record_event(
                "cache.outcome",
                &[
                    ("generated", TagValue::U64(search.events.generated as u64)),
                    ("loaded", TagValue::U64(search.events.loaded as u64)),
                    ("cache_hits", TagValue::U64(search.events.cache_hits as u64)),
                    ("thrash_faults", TagValue::U64(search.events.thrash_faults as u64)),
                ],
            );
        }

        // Fetch the matched chunks' text from storage (Fig. 9 step 6).
        let t_fetch = trace::clock();
        let ids: Vec<u32> = search.hits.iter().map(|&(id, _)| id).collect();
        let texts: Vec<String> = self.chunk_texts.get_many(&ids);
        let texts: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
        let fetch_bytes: u64 = texts.iter().map(|t| t.len() as u64).sum();
        if fetch_bytes > 0 {
            ledger.charge(
                Component::ChunkFetch,
                self.device.storage_read_cost(fetch_bytes, true),
            );
        }
        if let Some(t0) = t_fetch {
            trace::record_since(
                "chunk_fetch",
                t0,
                &[("bytes", TagValue::U64(fetch_bytes))],
            );
        }

        // Prompt assembly + prefill (the first-token half of TTFT).
        let t_prefill = trace::clock();
        let prompt = self.llm.build_prompt(query_text, &texts);
        let prefill = self.llm.prefill(&prompt, &mut ledger, self.real_prefill)?;
        if let Some(t0) = t_prefill {
            trace::record_since("prefill", t0, &[]);
        }

        let retrieval = ledger.retrieval();
        let ttft = ledger.total();

        // Apply the deferred cache mutations + adaptive-threshold feedback
        // (paper Alg. 3 sees this query's retrieval latency). Re-acquires
        // the read lease: an insert that slipped in between is handled by
        // the index's update-generation check.
        let t_commit = trace::clock();
        {
            let index = self.index.read().unwrap();
            index.commit(&search.intents, retrieval);
        }
        if let Some(t0) = t_commit {
            trace::record_since("commit", t0, &[]);
        }

        let breakdown = Breakdown::from_ledger(&ledger);
        self.metrics.record_query(&breakdown, retrieval, ttft);
        self.metrics.bump("generated", search.events.generated as u64);
        self.metrics.bump("loaded", search.events.loaded as u64);
        self.metrics.bump("cache_hits", search.events.cache_hits as u64);
        self.metrics
            .bump("thrash_faults", search.events.thrash_faults as u64);

        Ok(QueryOutcome {
            hits: search.hits,
            retrieval,
            ttft,
            breakdown,
            events: search.events,
            prompt_tokens: prefill.prompt_tokens,
            first_token: prefill.first_token,
            wall: wall_start.elapsed(),
        })
    }
}
