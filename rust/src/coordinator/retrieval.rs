//! The retrieval pipeline (paper Fig. 9 + the prefill tail of Fig. 6):
//! query embedding → index search → chunk fetch → prompt assembly →
//! prefill. Produces the TTFT breakdown every figure is built from.

use std::time::Instant;

use anyhow::Result;

use crate::config::DeviceProfile;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::texts::TextStore;
use crate::embedding::Embedder;
use crate::index::{SearchEvents, VectorIndex};
use crate::llm::Llm;
use crate::simtime::{Breakdown, Component, LatencyLedger, SimDuration};

/// One served query's full outcome.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    /// (chunk id, score), descending.
    pub hits: Vec<(u32, f32)>,
    /// Modeled retrieval latency (vector search side of TTFT).
    pub retrieval: SimDuration,
    /// Modeled time-to-first-token (retrieval + prefill + reloads).
    pub ttft: SimDuration,
    pub breakdown: Breakdown,
    pub events: SearchEvents,
    pub prompt_tokens: usize,
    /// Predicted first token (real prefill only).
    pub first_token: Option<i32>,
    /// Wall-clock coordinator time actually spent (L3 perf accounting).
    pub wall: std::time::Duration,
}

/// The serving pipeline: owns one index configuration plus the shared LLM.
pub struct RagPipeline {
    index: Box<dyn VectorIndex>,
    embedder: Embedder,
    llm: Llm,
    device: DeviceProfile,
    chunk_texts: TextStore,
    top_k: usize,
    real_prefill: bool,
    metrics: Metrics,
}

impl RagPipeline {
    pub fn new(
        index: Box<dyn VectorIndex>,
        embedder: Embedder,
        llm: Llm,
        device: DeviceProfile,
        chunk_texts: TextStore,
        top_k: usize,
        real_prefill: bool,
    ) -> Self {
        RagPipeline {
            index,
            embedder,
            llm,
            device,
            chunk_texts,
            top_k,
            real_prefill,
            metrics: Metrics::new(),
        }
    }

    pub fn index(&self) -> &dyn VectorIndex {
        self.index.as_ref()
    }

    pub fn index_mut(&mut self) -> &mut Box<dyn VectorIndex> {
        &mut self.index
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    /// The shared chunk-text store (the server appends to it on insert).
    pub fn texts(&self) -> TextStore {
        self.chunk_texts.clone()
    }

    /// Serve one query end to end.
    pub fn handle(&mut self, query_text: &str) -> Result<QueryOutcome> {
        let wall_start = Instant::now();
        let mut ledger = LatencyLedger::new();

        // Query embedding (same embedding model as indexing — Fig. 1b
        // step 1). Charged at the device's generation rate.
        ledger.charge(
            Component::QueryEmbed,
            self.device.embed_gen_cost(query_text.len() as u64),
        );
        let q = self.embedder.embed_one(query_text)?;

        // Vector search through the configured index.
        let search = self.index.search(&q, self.top_k)?;
        ledger.merge(&search.ledger);

        // Fetch the matched chunks' text from storage (Fig. 9 step 6).
        let ids: Vec<u32> = search.hits.iter().map(|&(id, _)| id).collect();
        let texts: Vec<String> = self.chunk_texts.get_many(&ids);
        let texts: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
        let fetch_bytes: u64 = texts.iter().map(|t| t.len() as u64).sum();
        if fetch_bytes > 0 {
            ledger.charge(
                Component::ChunkFetch,
                self.device.storage_read_cost(fetch_bytes, true),
            );
        }

        // Prompt assembly + prefill (the first-token half of TTFT).
        let prompt = self.llm.build_prompt(query_text, &texts);
        let prefill = self.llm.prefill(&prompt, &mut ledger, self.real_prefill)?;

        let retrieval = ledger.retrieval();
        let ttft = ledger.total();

        // Adaptive-threshold feedback (paper Alg. 3) sees retrieval latency.
        self.index.feedback(retrieval);

        let breakdown = Breakdown::from_ledger(&ledger);
        self.metrics.record_query(&breakdown, retrieval, ttft);
        self.metrics.bump("generated", search.events.generated as u64);
        self.metrics.bump("loaded", search.events.loaded as u64);
        self.metrics.bump("cache_hits", search.events.cache_hits as u64);
        self.metrics
            .bump("thrash_faults", search.events.thrash_faults as u64);

        Ok(QueryOutcome {
            hits: search.hits,
            retrieval,
            ttft,
            breakdown,
            events: search.events,
            prompt_tokens: prefill.prompt_tokens,
            first_token: prefill.first_token,
            wall: wall_start.elapsed(),
        })
    }
}
