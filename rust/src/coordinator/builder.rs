//! System builder: turns a [`DatasetProfile`] + [`IndexKind`] into a
//! ready-to-serve [`RagPipeline`] — corpus generation, embedding (with an
//! on-disk build cache), k-means clustering (paper Fig. 8), and index
//! construction.
//!
//! The embedding/k-means build cache mirrors the paper's methodology
//! (§6.2: "the embedding clustering process … is precomputed and shared
//! across all four configurations"): all index configurations of one
//! dataset share identical clustering.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{DatasetProfile, DeviceProfile, IndexKind, RetrievalConfig};
use crate::coordinator::retrieval::RagPipeline;
use crate::data::{Corpus, Workload};
use crate::embedding::{Embedder, EmbedderBackend};
use crate::index::kmeans::{kmeans, KMeansConfig};
use crate::index::{
    shared_memory, ClusterSet, EdgeIndex, EmbedSource, FlatIndex, IvfIndex, Scorer,
    ShardedEdgeIndex, VectorIndex,
};
use crate::llm::Llm;
use crate::runtime::ComputeHandle;
use crate::simtime::SimDuration;
use crate::storage::{BlobStore, WriteAheadLog};
use crate::vecmath::EmbeddingMatrix;

#[derive(Debug, Clone)]
pub struct BuildOptions {
    pub backend: EmbedderBackend,
    /// Execute the real compiled prefill graph per query (examples) or
    /// only charge its modeled cost (figure-scale benches).
    pub real_prefill: bool,
    /// Cache embeddings + clustering under this directory.
    pub cache_dir: Option<PathBuf>,
    /// Blob-store root (per dataset/config subdirs are created below it).
    pub state_dir: PathBuf,
    pub kmeans_iterations: usize,
    /// First-level size; defaults to the profile's topic count.
    pub nlist: Option<usize>,
    /// Serve online generation from the verified-equal prebuilt matrix
    /// (fast) instead of re-running the embedder (fully live).
    pub prebuilt_generation: bool,
    /// Clustering warm start: None = auto (topic means for ≥10k-chunk
    /// corpora, full k-means++ otherwise); Some(x) forces it. Topic-mean
    /// init preserves the corpus's tail-heavy natural cluster sizes that
    /// from-scratch k-means++ tends to balance away on uniform synthetic
    /// topics (DESIGN.md §7).
    pub topic_init: Option<bool>,
    /// Directory for the structural write-ahead log (only used when
    /// `retrieval.wal` is on). None derives
    /// `state_dir/{dataset}/{kind}-wal`, next to the blob layout.
    pub wal_dir: Option<PathBuf>,
}

impl Default for BuildOptions {
    fn default() -> Self {
        let target = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
        BuildOptions {
            backend: EmbedderBackend::Projection,
            real_prefill: false,
            cache_dir: Some(target.join("edgerag-cache")),
            state_dir: target.join("edgerag-state"),
            kmeans_iterations: 20, // paper §6.2
            nlist: None,
            prebuilt_generation: true,
            topic_init: None,
            wal_dir: None,
        }
    }
}

/// Everything shared across the index configurations of one dataset.
pub struct BuiltDataset {
    pub profile: DatasetProfile,
    pub corpus: Corpus,
    pub workload: Workload,
    pub embeddings: Arc<EmbeddingMatrix>,
    pub centroids: EmbeddingMatrix,
    pub assignment: Vec<u32>,
    pub chunk_texts: Arc<Vec<String>>,
}

impl BuiltDataset {
    pub fn cluster_set(&self, device: &DeviceProfile) -> ClusterSet {
        ClusterSet::build(
            &self.corpus,
            self.centroids.clone(),
            &self.assignment,
            device,
        )
    }
}

/// Builds datasets and pipelines against one compute executor + device.
#[derive(Clone)]
pub struct SystemBuilder {
    pub compute: ComputeHandle,
    pub device: DeviceProfile,
    pub retrieval: RetrievalConfig,
    pub options: BuildOptions,
}

impl SystemBuilder {
    pub fn new(compute: ComputeHandle, device: DeviceProfile) -> Self {
        SystemBuilder {
            compute,
            device,
            retrieval: RetrievalConfig::default(),
            options: BuildOptions::default(),
        }
    }

    /// A copy of this builder with an optional nprobe override (harness
    /// sweeps).
    pub fn clone_with_nprobe(&self, nprobe: Option<usize>) -> SystemBuilder {
        let mut b = self.clone();
        if let Some(np) = nprobe {
            b.retrieval.nprobe = np;
        }
        b
    }

    pub fn embedder(&self) -> Embedder {
        Embedder::new(self.compute.clone(), self.options.backend)
    }

    pub fn scorer(&self) -> Scorer {
        Scorer::new(self.compute.clone())
    }

    /// Generate corpus + workload, embed every chunk, cluster. Heavy steps
    /// are disk-cached keyed by (dataset, backend, nlist, iterations).
    pub fn build_dataset(&self, profile: &DatasetProfile) -> Result<BuiltDataset> {
        let corpus = Corpus::generate(profile);
        let workload = Workload::generate(profile, &corpus);
        let embedder = self.embedder();
        let scorer = self.scorer();
        let dim = scorer.dim();
        let nlist = self.options.nlist.unwrap_or(profile.n_topics);

        let key = format!(
            "{}-{}-s{}-n{}-t{}-d{}",
            profile.name,
            self.options.backend.name(),
            profile.seed,
            profile.n_chunks,
            profile.n_topics,
            dim
        );

        // ---- embeddings (cached) ----
        let emb_path = self
            .options
            .cache_dir
            .as_ref()
            .map(|d| d.join(format!("{key}.emb")));
        let embeddings = match emb_path.as_ref().and_then(|p| load_matrix(p, dim).ok()) {
            Some(m) if m.len() == corpus.len() => m,
            _ => {
                let texts = corpus.texts();
                let m = embedder.embed_texts(&texts)?;
                if let Some(p) = &emb_path {
                    save_matrix(p, &m)?;
                }
                m
            }
        };
        let embeddings = Arc::new(embeddings);

        // ---- clustering (cached) ----
        let km_path = self.options.cache_dir.as_ref().map(|d| {
            d.join(format!(
                "{key}-k{nlist}-i{}.km",
                self.options.kmeans_iterations
            ))
        });
        let (centroids, assignment) = match km_path
            .as_ref()
            .and_then(|p| load_kmeans(p, dim).ok())
        {
            Some((c, a)) if a.len() == corpus.len() => (c, a),
            _ => {
                // Large corpora warm-start from topic means (cheap, CPU)
                // and refine with a few Lloyd iterations — the balanced-IVF
                // configuration DESIGN.md §7 documents; small corpora run
                // the paper's full 20-iteration k-means++ from scratch.
                let auto = corpus.len() >= 10_000;
                let use_topics = self.options.topic_init.unwrap_or(auto)
                    && nlist == profile.n_topics;
                let (init, iterations) = if use_topics {
                    (Some(topic_means(&corpus, &embeddings, dim)), 3)
                } else {
                    (None, self.options.kmeans_iterations)
                };
                let km = kmeans(
                    &embeddings,
                    &KMeansConfig {
                        n_clusters: nlist,
                        iterations,
                        seed: profile.seed,
                        init,
                    },
                    &scorer,
                )?;
                if let Some(p) = &km_path {
                    save_kmeans(p, &km.centroids, &km.assignment)?;
                }
                (km.centroids, km.assignment)
            }
        };

        let chunk_texts = Arc::new(
            corpus
                .chunks
                .iter()
                .map(|c| c.text.clone())
                .collect::<Vec<_>>(),
        );
        Ok(BuiltDataset {
            profile: profile.clone(),
            corpus,
            workload,
            embeddings,
            centroids,
            assignment,
            chunk_texts,
        })
    }

    fn embed_source(&self, built: &BuiltDataset) -> EmbedSource {
        if self.options.prebuilt_generation {
            EmbedSource::Prebuilt(built.embeddings.clone())
        } else {
            // With batching on, on-demand cluster re-embedding goes
            // through its own cross-query embed stage so concurrent
            // queries generating different clusters fuse their kernel
            // calls (bit-identical rows either way).
            let batcher = self.retrieval.batching.then(|| {
                crate::sched::EmbedBatcher::new(
                    self.embedder(),
                    std::time::Duration::from_micros(self.retrieval.batch_window_us),
                )
            });
            EmbedSource::Live {
                embedder: self.embedder(),
                texts: built.chunk_texts.clone(),
                batcher,
            }
        }
    }

    /// Construct one of the five Table-4 index configurations.
    pub fn index(&self, built: &BuiltDataset, kind: IndexKind) -> Result<(Box<dyn VectorIndex>, crate::index::SharedMemory)> {
        let memory = shared_memory(self.device.mem_total_bytes);
        let scorer = self.scorer();
        let index: Box<dyn VectorIndex> = match kind {
            IndexKind::Flat => {
                let idx = FlatIndex::new(
                    built.embeddings.clone(),
                    scorer,
                    memory.clone(),
                    self.device.clone(),
                );
                idx.preload(); // Table 4: flat keeps embeddings in memory
                Box::new(idx)
            }
            IndexKind::Ivf => {
                let set = built.cluster_set(&self.device);
                let source = EmbedSource::Prebuilt(built.embeddings.clone());
                let cluster_embs = set
                    .clusters
                    .iter()
                    .map(|m| source.cluster_embeddings(m))
                    .collect::<Result<Vec<_>>>()?;
                let idx = IvfIndex::new(
                    set,
                    cluster_embs,
                    scorer,
                    memory.clone(),
                    self.device.clone(),
                    self.retrieval.nprobe,
                );
                idx.preload(); // Table 4: IVF keeps both levels in memory
                Box::new(idx)
            }
            IndexKind::IvfGen | IndexKind::IvfGenLoad | IndexKind::EdgeRag => {
                let set = built.cluster_set(&self.device);
                let store_limit = SimDuration::from_secs_f64(
                    built.profile.slo().as_secs_f64() * self.retrieval.store_slo_fraction,
                );
                let shards = self.retrieval.resolved_shards();
                if shards > 1 {
                    // Sharded serving path: clusters partitioned across
                    // independently locked shards (`shards` knob; see
                    // docs/ARCHITECTURE.md). Blob state lives under a
                    // sharded-specific subdir so it never collides with
                    // the single-shard layout.
                    let blob_dir = kind.uses_storage().then(|| {
                        self.options
                            .state_dir
                            .join(&built.profile.name)
                            .join(format!("{}-sharded", kind.name()))
                    });
                    let mut idx = ShardedEdgeIndex::build(
                        kind,
                        set,
                        self.embed_source(built),
                        blob_dir.as_deref(),
                        scorer,
                        memory.clone(),
                        self.device.clone(),
                        &self.retrieval,
                        store_limit,
                        built.profile.slo(),
                        shards,
                    )?;
                    // Startup recovery: replay the surviving snapshot+tail
                    // through the ordinary update path, then attach the
                    // log (strictly after — replayed ops are not
                    // re-logged). `ShardedEdgeIndex::build` is a pure
                    // function of the dataset, so replay lands on exactly
                    // the structure the records were logged against.
                    if let Some(wal) = self.open_wal(built, kind)? {
                        let ops = wal.take_recovered();
                        idx.replay_wal(&ops)?;
                        idx.attach_wal(wal);
                    }
                    Box::new(idx)
                } else {
                    let blob = if kind.uses_storage() {
                        let dir = self
                            .options
                            .state_dir
                            .join(&built.profile.name)
                            .join(kind.name());
                        Some(BlobStore::open(&dir, self.scorer().dim())?)
                    } else {
                        None
                    };
                    let mut idx = EdgeIndex::build(
                        kind,
                        set,
                        self.embed_source(built),
                        blob,
                        scorer,
                        memory.clone(),
                        self.device.clone(),
                        &self.retrieval,
                        store_limit,
                        built.profile.slo(),
                    )?;
                    if let Some(wal) = self.open_wal(built, kind)? {
                        let ops = wal.take_recovered();
                        idx.replay_wal(&ops)?;
                        idx.attach_wal(wal);
                    }
                    Box::new(idx)
                }
            }
        };
        Ok((index, memory))
    }

    /// Open — and crash-recover — the structural write-ahead log for one
    /// configuration, when `retrieval.wal` is on. The directory is
    /// `options.wal_dir`, or the derived
    /// `state_dir/{dataset}/{kind}-wal` next to the blob layout. The
    /// returned log still holds its recovered ops
    /// ([`WriteAheadLog::take_recovered`]); the caller replays them
    /// before attaching.
    fn open_wal(
        &self,
        built: &BuiltDataset,
        kind: IndexKind,
    ) -> Result<Option<Arc<WriteAheadLog>>> {
        if !self.retrieval.wal {
            return Ok(None);
        }
        let dir = self.options.wal_dir.clone().unwrap_or_else(|| {
            self.options
                .state_dir
                .join(&built.profile.name)
                .join(format!("{}-wal", kind.name()))
        });
        Ok(Some(Arc::new(WriteAheadLog::open(
            &dir,
            self.retrieval.snapshot_interval_ops,
        )?)))
    }

    /// Wrap an engine in the cross-query batch scheduler configured from
    /// this builder's retrieval knobs (`batching`, `batch_window_us`,
    /// `max_inflight`). The caller decides whether to serve through it.
    pub fn scheduler(
        &self,
        engine: std::sync::Arc<RagPipeline>,
    ) -> std::sync::Arc<crate::sched::BatchScheduler> {
        crate::sched::BatchScheduler::new(
            engine,
            crate::sched::SchedConfig::from_retrieval(&self.retrieval),
        )
    }

    /// Assemble the full serving engine for one configuration. The result
    /// is shared-ready: wrap it in an `Arc` and call `handle` from any
    /// number of threads.
    pub fn pipeline(&self, built: &BuiltDataset, kind: IndexKind) -> Result<RagPipeline> {
        let (index, memory) = self.index(built, kind)?;
        let llm = Llm::new(
            self.device.clone(),
            memory,
            Some(self.compute.clone()),
            self.retrieval.max_prompt_tokens,
        );
        Ok(RagPipeline::new(
            index,
            self.embedder(),
            llm,
            self.device.clone(),
            crate::coordinator::texts::TextStore::new(built.chunk_texts.to_vec()),
            self.retrieval.top_k,
            self.options.real_prefill,
        ))
    }
}

/// Unit-normalized per-topic mean embeddings (k-means warm start).
fn topic_means(corpus: &Corpus, embeddings: &EmbeddingMatrix, dim: usize) -> EmbeddingMatrix {
    let mut sums = vec![0.0f64; corpus.n_topics * dim];
    let mut counts = vec![0usize; corpus.n_topics];
    for (i, chunk) in corpus.chunks.iter().enumerate() {
        let t = chunk.topic as usize;
        counts[t] += 1;
        for (s, v) in sums[t * dim..(t + 1) * dim].iter_mut().zip(embeddings.row(i)) {
            *s += *v as f64;
        }
    }
    let mut m = EmbeddingMatrix::with_capacity(dim, corpus.n_topics);
    for t in 0..corpus.n_topics {
        let k = counts[t].max(1) as f64;
        let mut row: Vec<f32> = sums[t * dim..(t + 1) * dim]
            .iter()
            .map(|&s| (s / k) as f32)
            .collect();
        let norm = crate::vecmath::l2_norm(&row).max(1e-9);
        for v in &mut row {
            *v /= norm;
        }
        m.push(&row);
    }
    m
}

// ---------------------------------------------------------------------------
// Build cache persistence (raw little-endian blobs + tiny headers)
// ---------------------------------------------------------------------------

fn save_matrix(path: &Path, m: &EmbeddingMatrix) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut bytes = Vec::with_capacity(8 + m.data.len() * 4);
    bytes.extend_from_slice(&(m.dim as u32).to_le_bytes());
    bytes.extend_from_slice(&(m.len() as u32).to_le_bytes());
    for v in &m.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

fn load_matrix(path: &Path, expect_dim: usize) -> Result<EmbeddingMatrix> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() >= 8, "truncated matrix file");
    let dim = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    let n = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
    anyhow::ensure!(dim == expect_dim, "dim mismatch");
    anyhow::ensure!(bytes.len() == 8 + n * dim * 4, "size mismatch");
    let data = bytes[8..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(EmbeddingMatrix { dim, data })
}

fn save_kmeans(path: &Path, centroids: &EmbeddingMatrix, assignment: &[u32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(centroids.dim as u32).to_le_bytes());
    bytes.extend_from_slice(&(centroids.len() as u32).to_le_bytes());
    bytes.extend_from_slice(&(assignment.len() as u32).to_le_bytes());
    for v in &centroids.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for a in assignment {
        bytes.extend_from_slice(&a.to_le_bytes());
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

fn load_kmeans(path: &Path, expect_dim: usize) -> Result<(EmbeddingMatrix, Vec<u32>)> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() >= 12, "truncated kmeans file");
    let dim = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    let k = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
    let n = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
    anyhow::ensure!(dim == expect_dim, "dim mismatch");
    let cent_bytes = k * dim * 4;
    anyhow::ensure!(bytes.len() == 12 + cent_bytes + n * 4, "size mismatch");
    let data: Vec<f32> = bytes[12..12 + cent_bytes]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let assignment: Vec<u32> = bytes[12 + cent_bytes..]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((EmbeddingMatrix { dim, data }, assignment))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_cache_roundtrip() {
        let dir = std::env::temp_dir().join(format!("edgerag-bc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("m.emb");
        let m = EmbeddingMatrix::from_rows(3, &[vec![1., 2., 3.], vec![4., 5., 6.]]);
        save_matrix(&p, &m).unwrap();
        let back = load_matrix(&p, 3).unwrap();
        assert_eq!(back.data, m.data);
        assert!(load_matrix(&p, 4).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kmeans_cache_roundtrip() {
        let dir = std::env::temp_dir().join(format!("edgerag-kc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("k.km");
        let c = EmbeddingMatrix::from_rows(2, &[vec![0.1, 0.2]]);
        let a = vec![0u32, 0, 0, 0, 0];
        save_kmeans(&p, &c, &a).unwrap();
        let (c2, a2) = load_kmeans(&p, 2).unwrap();
        assert_eq!(c2.data, c.data);
        assert_eq!(a2, a);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
