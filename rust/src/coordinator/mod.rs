//! The serving coordinator: the retrieval pipeline (Fig. 9), system
//! builder, serving metrics and SLO accounting.

pub mod builder;
pub mod metrics;
pub mod retrieval;
pub mod texts;

pub use builder::{BuildOptions, BuiltDataset, SystemBuilder};
pub use metrics::{LatencySeries, Metrics};
pub use retrieval::{Engine, QueryOutcome, RagPipeline};
pub use texts::TextStore;
