//! Shared fixed-size worker pool over an mpsc job queue.
//!
//! One implementation serves the three places the serving stack needs a
//! pool of plain threads draining a queue of boxed jobs:
//!
//! * the index shard pool (`index::shard`) — per-(query, shard) cluster
//!   walks fanned out by [`crate::index::ShardedEdgeIndex`];
//! * the request server's worker pool (`server`) — bounded admission of
//!   client requests against the shared engine;
//! * the batch scheduler (`sched`) — fused-kernel stage execution.
//!
//! Design points shared by all three (previously duplicated):
//!
//! * workers are detached threads over one `Mutex`-guarded receiver, so
//!   dropping the pool never blocks on an in-flight job;
//! * a panicking job fails only its own caller (the caller observes its
//!   reply channel closing), never the worker — jobs run under
//!   `catch_unwind`;
//! * the queue closes when every submission handle drops; workers drain
//!   what is left and exit.
//!
//! The queue is unbounded by default; [`WorkerPool::bounded`] caps it so
//! submissions can be *rejected* (backpressure) instead of queueing
//! without limit.

use std::sync::{mpsc, Arc, Mutex};

/// A unit of work for the pool.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Why a submission was refused. The job is handed back so the caller can
/// run it inline or fail the request.
pub enum SubmitError {
    /// Bounded queue at capacity (backpressure; bounded pools only).
    Full(Job),
    /// Pool has no workers or its queue has closed.
    Closed(Job),
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full(_) => f.write_str("SubmitError::Full"),
            SubmitError::Closed(_) => f.write_str("SubmitError::Closed"),
        }
    }
}

enum Queue {
    Unbounded(mpsc::Sender<Job>),
    Bounded(mpsc::SyncSender<Job>),
}

/// Cloneable submission handle. All handles share one queue; the queue
/// closes (and workers exit after draining) once every handle — including
/// the pool's own — has dropped.
#[derive(Clone)]
pub struct PoolHandle {
    /// `Mutex` so the handle is `Sync` on every supported toolchain; held
    /// only for the (non-blocking) enqueue.
    tx: Arc<Mutex<Queue>>,
    workers: usize,
}

impl PoolHandle {
    /// Enqueue a job. Never blocks: a bounded pool at capacity refuses
    /// with [`SubmitError::Full`]; a pool with zero workers (or a closed
    /// queue) refuses with [`SubmitError::Closed`] so the caller can run
    /// the job inline.
    pub fn submit(&self, job: Job) -> Result<(), SubmitError> {
        if self.workers == 0 {
            return Err(SubmitError::Closed(job));
        }
        let guard = match self.tx.lock() {
            Ok(g) => g,
            Err(_) => return Err(SubmitError::Closed(job)),
        };
        match &*guard {
            Queue::Unbounded(tx) => tx.send(job).map_err(|e| SubmitError::Closed(e.0)),
            Queue::Bounded(tx) => match tx.try_send(job) {
                Ok(()) => Ok(()),
                Err(mpsc::TrySendError::Full(job)) => Err(SubmitError::Full(job)),
                Err(mpsc::TrySendError::Disconnected(job)) => Err(SubmitError::Closed(job)),
            },
        }
    }

    /// Number of worker threads behind this handle.
    pub fn workers(&self) -> usize {
        self.workers
    }
}

/// Fixed-size worker pool. Dropping the pool drops its handle; workers
/// exit once every cloned [`PoolHandle`] is gone and the queue drains.
pub struct WorkerPool {
    handle: PoolHandle,
}

impl WorkerPool {
    /// Unbounded queue, `workers` threads named `{name}-{i}`. With
    /// `workers == 0` no threads spawn and every submit hands the job
    /// back ([`SubmitError::Closed`]) for inline execution.
    pub fn new(name: &str, workers: usize) -> WorkerPool {
        let (tx, rx) = mpsc::channel::<Job>();
        Self::spawn_workers(name, workers, rx);
        WorkerPool {
            handle: PoolHandle {
                tx: Arc::new(Mutex::new(Queue::Unbounded(tx))),
                workers,
            },
        }
    }

    /// Bounded queue of at most `queue` waiting jobs — submissions beyond
    /// that are refused with [`SubmitError::Full`] (admission control).
    pub fn bounded(name: &str, workers: usize, queue: usize) -> WorkerPool {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue.max(1));
        Self::spawn_workers(name, workers, rx);
        WorkerPool {
            handle: PoolHandle {
                tx: Arc::new(Mutex::new(Queue::Bounded(tx))),
                workers,
            },
        }
    }

    fn spawn_workers(name: &str, workers: usize, rx: mpsc::Receiver<Job>) {
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only for the dequeue.
                    let job = match rx.lock() {
                        Ok(guard) => match guard.recv() {
                            Ok(job) => job,
                            Err(_) => break, // queue closed: drained, exit
                        },
                        Err(_) => break, // queue mutex poisoned: stop cleanly
                    };
                    // Panic isolation: a panicking job fails only its own
                    // caller, not the worker.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                })
                .expect("spawning pool worker thread");
        }
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handle.workers
    }

    /// Enqueue on the pool's own handle (see [`PoolHandle::submit`]).
    pub fn submit(&self, job: Job) -> Result<(), SubmitError> {
        self.handle.submit(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_drains_on_drop() {
        let pool = WorkerPool::new("test-pool", 2);
        let done = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..16 {
            let done = done.clone();
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                done.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            }))
            .unwrap();
        }
        for _ in 0..16 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(done.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn zero_workers_hands_job_back() {
        let pool = WorkerPool::new("test-zero", 0);
        let res = pool.submit(Box::new(|| {}));
        match res {
            Err(SubmitError::Closed(job)) => job(), // caller runs inline
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn bounded_pool_rejects_when_full() {
        // One worker blocked on a gate; the queue holds one job; the next
        // submission must be refused with Full.
        let pool = WorkerPool::bounded("test-bounded", 1, 1);
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let gr = gate_rx.clone();
        pool.submit(Box::new(move || {
            let _ = gr.lock().unwrap().recv();
        }))
        .unwrap();
        // Fill the one queue slot (retry until the worker has dequeued
        // the blocker so the slot is actually free).
        let mut second: Job = {
            let gr = gate_rx.clone();
            Box::new(move || {
                let _ = gr.lock().unwrap().recv();
            })
        };
        loop {
            match pool.submit(second) {
                Ok(()) => break,
                Err(SubmitError::Full(job)) => {
                    second = job;
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(other) => panic!("unexpected {other:?}"),
            }
        }
        let refused = pool.submit(Box::new(|| {}));
        assert!(matches!(refused, Err(SubmitError::Full(_))), "{refused:?}");
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
    }

    #[test]
    fn panicking_job_does_not_kill_worker() {
        let pool = WorkerPool::new("test-panic", 1);
        pool.submit(Box::new(|| panic!("boom"))).unwrap();
        let (tx, rx) = mpsc::channel();
        pool.submit(Box::new(move || {
            let _ = tx.send(());
        }))
        .unwrap();
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("worker survived the panic");
    }
}
