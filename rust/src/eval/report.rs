//! Plain-text table rendering for experiment reports (what the benches and
//! `edgerag bench` print — the textual analogue of the paper's figures).

/// A simple aligned-column table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..cols {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

/// Format a millisecond quantity compactly.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2}s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.0}ms")
    } else {
        format!("{:.2}ms", ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer-name", "123456"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].starts_with("a "));
        // right-aligned numeric column
        assert!(lines[3].ends_with("123456"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.0 MiB");
        assert_eq!(fmt_bytes(5 << 30), "5.0 GiB");
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(0.5), "0.50ms");
        assert_eq!(fmt_ms(42.0), "42ms");
        assert_eq!(fmt_ms(2500.0), "2.50s");
    }
}
