//! One function per paper table/figure (DESIGN.md §5 experiment index).
//! Shared by the `benches/` binaries and the `edgerag bench` CLI; each
//! returns the rendered report it printed, so tests can assert on the
//! reproduced *shape* (who wins, crossovers, ratios).

use anyhow::Result;

use crate::config::{DatasetProfile, DeviceProfile, IndexKind};
use crate::coordinator::builder::{BuiltDataset, SystemBuilder};
use crate::eval::harness::{dataset_stats, run_workload, RunOptions};
use crate::eval::report::{fmt_bytes, fmt_ms, Table};
use crate::simtime::Component;

/// Default per-run query budget: full workloads take tens of minutes of
/// real PJRT compute on this testbed; a deterministic prefix keeps every
/// figure reproducible in minutes. `--full` lifts it.
pub const DEFAULT_QUERY_LIMIT: usize = 150;

pub struct ExperimentCtx {
    pub builder: SystemBuilder,
    pub query_limit: Option<usize>,
}

impl ExperimentCtx {
    pub fn opts(&self) -> RunOptions {
        RunOptions {
            query_limit: self.query_limit,
            // Steady-state serving: cold-start residency faults are
            // excluded (the paper measures a warmed serving system).
            warmup: 32,
            ..Default::default()
        }
    }

    pub fn build(&self, name: &str) -> Result<BuiltDataset> {
        let profile = DatasetProfile::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset `{name}`"))?;
        self.builder.build_dataset(&profile)
    }
}

/// Table 2: evaluated dataset statistics.
pub fn table2(ctx: &ExperimentCtx) -> Result<String> {
    let dim = ctx.builder.compute.dim();
    let mut t = Table::new(vec![
        "dataset", "corpus", "records", "embeddings", "unique", "total", "reuse", "fits",
    ]);
    for p in DatasetProfile::beir_suite() {
        let built = ctx.builder.build_dataset(&p)?;
        let s = dataset_stats(&built, dim);
        t.row(vec![
            p.name.clone(),
            fmt_bytes(s.get("corpus_bytes").unwrap().as_u64().unwrap()),
            format!("{}", built.corpus.len()),
            fmt_bytes(s.get("embedding_bytes").unwrap().as_u64().unwrap()),
            format!("{}", s.get("unique_access").unwrap().as_u64().unwrap()),
            format!("{}", s.get("total_access").unwrap().as_u64().unwrap()),
            format!("{:.2}", s.get("reuse_ratio").unwrap().as_f64().unwrap()),
            if s.get("fits_in_memory").unwrap().as_bool().unwrap() {
                "yes".into()
            } else {
                "NO".to_string()
            },
        ]);
    }
    let out = format!("Table 2 — evaluated datasets (1:100 scale)\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Fig. 3: RAG latency breakdown (retrieval / first-token) and embedded DB
/// size vs. device memory, Flat vs IVF across datasets.
pub fn fig3(ctx: &ExperimentCtx) -> Result<String> {
    let device = &ctx.builder.device;
    let budget = device.mem_total_bytes;
    let mut t = Table::new(vec![
        "dataset", "config", "db-size", "mem", "retrieval", "first-token", "ttft", "thrash",
    ]);
    for p in DatasetProfile::beir_suite() {
        let built = ctx.builder.build_dataset(&p)?;
        for kind in [IndexKind::Flat, IndexKind::Ivf] {
            let r = run_workload(&ctx.builder, &built, kind, &ctx.opts())?;
            let first_token = r.ttft_mean.saturating_sub(r.retrieval_mean);
            t.row(vec![
                p.name.clone(),
                kind.name().to_string(),
                fmt_bytes(r.resident_bytes),
                fmt_bytes(budget),
                fmt_ms(r.retrieval_mean.as_millis_f64()),
                fmt_ms(first_token.as_millis_f64()),
                fmt_ms(r.ttft_mean.as_millis_f64()),
                format!("{}", r.thrash_faults),
            ]);
        }
    }
    let out = format!(
        "Fig. 3 — latency breakdown & DB size vs memory ({})\n{}",
        device.name,
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 4: embedding-generation rate vs. storage-load rate across cluster
/// sizes; prints the crossover (paper: ~24 kB of cluster text).
pub fn fig4(ctx: &ExperimentCtx) -> Result<String> {
    let device = &ctx.builder.device;
    let mut t = Table::new(vec![
        "cluster-chars", "emb-bytes", "gen", "load(scattered)", "load(blob)", "winner",
    ]);
    let mut crossover: Option<u64> = None;
    let mut prev_gen_wins = true;
    for chars in [1_500u64, 3_000, 6_000, 12_000, 24_000, 48_000, 96_000, 192_000, 384_000] {
        let emb_bytes = chars / 256 * 1024; // 256-char chunks, 1 KiB/chunk
        let gen = device.embed_gen_cost(chars);
        let scat = device.storage_read_cost(emb_bytes, false);
        let blob = device.storage_read_cost(emb_bytes, true);
        let gen_wins = gen < scat;
        if prev_gen_wins && !gen_wins && crossover.is_none() {
            crossover = Some(chars);
        }
        prev_gen_wins = gen_wins;
        t.row(vec![
            format!("{chars}"),
            fmt_bytes(emb_bytes),
            fmt_ms(gen.as_millis_f64()),
            fmt_ms(scat.as_millis_f64()),
            fmt_ms(blob.as_millis_f64()),
            if gen_wins { "generate" } else { "load" }.to_string(),
        ]);
    }
    let out = format!(
        "Fig. 4 — embedding generation vs load, crossover ≈ {} chars (paper: ~24000)\n{}",
        crossover.map_or("none".to_string(), |c| c.to_string()),
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 5: distribution of per-cluster embedding-generation cost (nq).
pub fn fig5(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let built = ctx.build(dataset)?;
    let set = built.cluster_set(&ctx.builder.device);
    let mut costs: Vec<f64> = set
        .clusters
        .iter()
        .filter(|c| !c.is_empty())
        .map(|c| c.gen_cost.as_millis_f64())
        .collect();
    costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = costs.len();
    let pct = |p: f64| costs[((p / 100.0 * n as f64) as usize).min(n - 1)];

    // Histogram over log-spaced buckets (the paper's Fig. 5 x-axis).
    let buckets = [50.0, 100.0, 250.0, 500.0, 1000.0, 2000.0, f64::INFINITY];
    let mut t = Table::new(vec!["gen-latency", "clusters", "bar"]);
    let mut lo = 0.0;
    for &hi in &buckets {
        let count = costs.iter().filter(|&&c| c >= lo && c < hi).count();
        let label = if hi.is_infinite() {
            format!(">{:.0}ms", lo)
        } else {
            format!("{:.0}-{:.0}ms", lo, hi)
        };
        t.row(vec![label, format!("{count}"), "#".repeat(count * 60 / n.max(1))]);
        lo = hi;
    }
    let out = format!(
        "Fig. 5 — cluster gen-cost distribution ({dataset}): median {} p95 {} max {} (tail-heavy: p95/median {:.1}×)\n{}",
        fmt_ms(pct(50.0)),
        fmt_ms(pct(95.0)),
        fmt_ms(*costs.last().unwrap()),
        pct(95.0) / pct(50.0).max(1e-9),
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 7: retrieval latency + cache hit rate across pinned Minimum
/// Latency Caching Thresholds (fever).
pub fn fig7(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let built = ctx.build(dataset)?;
    let mut t = Table::new(vec!["threshold", "retrieval(mean)", "hit-rate", "cache-bytes"]);
    // The cache's reuse effect needs a longer window than the default
    // query budget: floor at 400 queries.
    let opts_long = RunOptions {
        query_limit: Some(ctx.query_limit.unwrap_or(usize::MAX).max(400)),
        ..ctx.opts()
    };
    for threshold in [0.0, 25.0, 50.0, 100.0, 200.0, 400.0, 800.0, 1600.0] {
        let r = run_workload(
            &ctx.builder,
            &built,
            IndexKind::EdgeRag,
            &RunOptions {
                pin_threshold_ms: Some(threshold),
                ..opts_long.clone()
            },
        )?;
        t.row(vec![
            fmt_ms(threshold),
            fmt_ms(r.retrieval_mean.as_millis_f64()),
            format!("{:.1}%", r.cache.map(|c| c.hit_rate()).unwrap_or(0.0) * 100.0),
            fmt_bytes(r.cache_used_bytes),
        ]);
    }
    // Adaptive run for comparison.
    let adaptive = run_workload(&ctx.builder, &built, IndexKind::EdgeRag, &opts_long)?;
    t.row(vec![
        format!("adaptive→{}", fmt_ms(adaptive.threshold_ms)),
        fmt_ms(adaptive.retrieval_mean.as_millis_f64()),
        format!(
            "{:.1}%",
            adaptive.cache.map(|c| c.hit_rate()).unwrap_or(0.0) * 100.0
        ),
        fmt_bytes(adaptive.cache_used_bytes),
    ]);
    let out = format!(
        "Fig. 7 — minimum caching threshold sweep ({dataset})\n{}",
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 10 + Fig. 11: precision/recall and generation-quality scores,
/// Flat vs IVF family, per dataset.
pub fn fig10_11(ctx: &ExperimentCtx) -> Result<String> {
    let mut t = Table::new(vec![
        "dataset", "config", "recall", "precision", "gen-score",
    ]);
    for p in DatasetProfile::beir_suite() {
        let built = ctx.builder.build_dataset(&p)?;
        for kind in [IndexKind::Flat, IndexKind::EdgeRag] {
            let r = run_workload(&ctx.builder, &built, kind, &ctx.opts())?;
            t.row(vec![
                p.name.clone(),
                kind.name().to_string(),
                format!("{:.3}", r.quality.recall),
                format!("{:.3}", r.quality.precision),
                format!("{:.1}", r.gen_score),
            ]);
        }
    }
    let out = format!(
        "Fig. 10/11 — retrieval quality (BEIR-style) + generation score\n{}",
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 12: retrieval-latency distribution per optimization stage (nq).
pub fn fig12(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let built = ctx.build(dataset)?;
    let mut t = Table::new(vec![
        "config", "p50", "p95", "p99", "p95/p50", "gen", "loads", "cache-hits", "thrash",
    ]);
    let mut rows = Vec::new();
    for kind in [
        IndexKind::Ivf,
        IndexKind::IvfGen,
        IndexKind::IvfGenLoad,
        IndexKind::EdgeRag,
    ] {
        let r = run_workload(&ctx.builder, &built, kind, &ctx.opts())?;
        let ratio = r.retrieval_p95.as_millis_f64() / r.retrieval_p50.as_millis_f64().max(1e-9);
        t.row(vec![
            kind.name().to_string(),
            fmt_ms(r.retrieval_p50.as_millis_f64()),
            fmt_ms(r.retrieval_p95.as_millis_f64()),
            fmt_ms(r.retrieval_p99.as_millis_f64()),
            format!("{ratio:.1}×"),
            format!("{}", r.mean_by_component.iter().find(|(n, _)| *n == "embed_gen").map(|(_, d)| fmt_ms(d.as_millis_f64())).unwrap_or_default()),
            format!("{}", r.stored_clusters),
            format!("{:.0}%", r.cache.map(|c| c.hit_rate()).unwrap_or(0.0) * 100.0),
            format!("{}", r.thrash_faults),
        ]);
        rows.push((kind, r));
    }
    let ivf_p95 = rows[0].1.retrieval_p95.as_millis_f64();
    let gen_p95 = rows[1].1.retrieval_p95.as_millis_f64();
    let load_p95 = rows[2].1.retrieval_p95.as_millis_f64();
    let edge_p95 = rows[3].1.retrieval_p95.as_millis_f64();
    let out = format!(
        "Fig. 12 — retrieval latency distribution ({dataset})\n{}\np95 reductions: +gen {:.1}×, +load {:.1}×, +cache(EdgeRAG) {:.1}× vs IVF\n",
        t.render(),
        ivf_p95 / gen_p95.max(1e-9),
        gen_p95 / load_p95.max(1e-9),
        ivf_p95 / edge_p95.max(1e-9),
    );
    println!("{out}");
    Ok(out)
}

/// Fig. 13: retrieval + first-token latency (TTFT), all five configs ×
/// all datasets; plus the headline aggregates (§6.3.4 / abstract).
pub fn fig13(ctx: &ExperimentCtx) -> Result<String> {
    let mut t = Table::new(vec![
        "dataset", "config", "retrieval", "first-token", "ttft", "slo-ok",
    ]);
    let mut speedups: Vec<f64> = Vec::new();
    let mut large_speedups: Vec<f64> = Vec::new();
    for p in DatasetProfile::beir_suite() {
        let built = ctx.builder.build_dataset(&p)?;
        let mut ivf_ttft = None;
        for kind in IndexKind::ALL {
            let r = run_workload(&ctx.builder, &built, kind, &ctx.opts())?;
            let first_token = r.ttft_mean.saturating_sub(r.retrieval_mean);
            if kind == IndexKind::Ivf {
                ivf_ttft = Some(r.ttft_mean);
            }
            if kind == IndexKind::EdgeRag {
                let s = ivf_ttft.unwrap().as_secs_f64() / r.ttft_mean.as_secs_f64().max(1e-12);
                speedups.push(s);
                if p.n_chunks > 16_000 {
                    large_speedups.push(s);
                }
            }
            t.row(vec![
                p.name.clone(),
                kind.name().to_string(),
                fmt_ms(r.retrieval_mean.as_millis_f64()),
                fmt_ms(first_token.as_millis_f64()),
                fmt_ms(r.ttft_mean.as_millis_f64()),
                format!("{:.0}%", r.slo_attainment * 100.0),
            ]);
        }
    }
    let gmean = |xs: &[f64]| {
        if xs.is_empty() {
            return 0.0;
        }
        (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
    };
    let out = format!(
        "Fig. 13 — TTFT across configs (paper: EdgeRAG 1.8× avg, 3.82× large vs IVF)\n{}\nEdgeRAG TTFT speedup vs IVF: avg {:.2}×, large datasets {:.2}×\n",
        t.render(),
        gmean(&speedups),
        gmean(&large_speedups),
    );
    println!("{out}");
    Ok(out)
}

/// Headline numbers (abstract + §6.3.4): EdgeRAG vs IVF TTFT, quality
/// delta vs Flat, cache memory overhead.
pub fn headline(ctx: &ExperimentCtx) -> Result<String> {
    let mut speedups = Vec::new();
    let mut large = Vec::new();
    let mut recall_deltas = Vec::new();
    let mut gen_deltas = Vec::new();
    let mut cache_fracs = Vec::new();
    for p in DatasetProfile::beir_suite() {
        let built = ctx.builder.build_dataset(&p)?;
        let flat = run_workload(&ctx.builder, &built, IndexKind::Flat, &ctx.opts())?;
        let ivf = run_workload(&ctx.builder, &built, IndexKind::Ivf, &ctx.opts())?;
        let edge = run_workload(&ctx.builder, &built, IndexKind::EdgeRag, &ctx.opts())?;
        let s = ivf.ttft_mean.as_secs_f64() / edge.ttft_mean.as_secs_f64().max(1e-12);
        speedups.push(s);
        if p.n_chunks > 16_000 {
            large.push(s);
        }
        recall_deltas.push(flat.quality.recall - edge.quality.recall);
        gen_deltas.push((flat.gen_score - edge.gen_score) / flat.gen_score.max(1e-9));
        cache_fracs.push(
            edge.cache_used_bytes as f64 / ctx.builder.device.mem_total_bytes as f64,
        );
    }
    let avg = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let gmean = |xs: &[f64]| {
        (xs.iter().map(|x: &f64| x.ln()).sum::<f64>() / xs.len().max(1) as f64).exp()
    };
    let out = format!(
        "Headline (paper → measured):\n\
         · TTFT speedup vs IVF, average:        1.8×  → {:.2}×\n\
         · TTFT speedup vs IVF, large datasets: 3.82× → {:.2}×\n\
         · recall delta vs Flat (≤5%):          {:.1}%\n\
         · generation-score delta vs Flat (≤5%): {:.1}%\n\
         · cache memory overhead (≈7%):          {:.1}%\n",
        gmean(&speedups),
        gmean(&large),
        avg(&recall_deltas) * 100.0,
        avg(&gen_deltas) * 100.0,
        avg(&cache_fracs) * 100.0,
    );
    println!("{out}");
    Ok(out)
}

/// Ablation: storage-device sensitivity (SD card vs NVMe vs server-class)
/// for the EdgeRAG configuration on one large dataset.
pub fn ablation_storage(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let mut t = Table::new(vec!["device", "retrieval(mean)", "p95", "ttft"]);
    for device in [
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::edge_nvme(),
        DeviceProfile::server_l40(),
    ] {
        let mut builder = ctx.builder.clone();
        builder.device = device.clone();
        let built = builder.build_dataset(
            &DatasetProfile::by_name(dataset)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?,
        )?;
        let r = run_workload(&builder, &built, IndexKind::EdgeRag, &ctx.opts())?;
        t.row(vec![
            device.name.clone(),
            fmt_ms(r.retrieval_mean.as_millis_f64()),
            fmt_ms(r.retrieval_p95.as_millis_f64()),
            fmt_ms(r.ttft_mean.as_millis_f64()),
        ]);
    }
    let out = format!("Ablation — storage sensitivity ({dataset})\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Ablation: cache decay factor sweep.
pub fn ablation_decay(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let built = ctx.build(dataset)?;
    let mut t = Table::new(vec!["decay", "retrieval(mean)", "hit-rate"]);
    for decay in [0.5, 0.8, 0.9, 0.95, 1.0] {
        let mut builder = ctx.builder.clone();
        builder.retrieval.cache_decay = decay;
        let r = run_workload(&builder, &built, IndexKind::EdgeRag, &ctx.opts())?;
        t.row(vec![
            format!("{decay}"),
            fmt_ms(r.retrieval_mean.as_millis_f64()),
            format!("{:.1}%", r.cache.map(|c| c.hit_rate()).unwrap_or(0.0) * 100.0),
        ]);
    }
    let out = format!("Ablation — cache decay factor ({dataset})\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Which component dominates mean retrieval per config (Fig. 6 timing
/// narrative).
pub fn breakdown(ctx: &ExperimentCtx, dataset: &str) -> Result<String> {
    let built = ctx.build(dataset)?;
    let mut t = Table::new(vec![
        "config", "query-embed", "centroid", "gen", "load", "cache", "search", "thrash",
    ]);
    for kind in IndexKind::ALL {
        let r = run_workload(&ctx.builder, &built, kind, &ctx.opts())?;
        let get = |c: Component| {
            r.mean_by_component
                .iter()
                .find(|(n, _)| *n == c.name())
                .map(|(_, d)| fmt_ms(d.as_millis_f64()))
                .unwrap_or_default()
        };
        t.row(vec![
            kind.name().to_string(),
            get(Component::QueryEmbed),
            get(Component::CentroidProbe),
            get(Component::EmbedGen),
            get(Component::StorageLoad),
            get(Component::CacheHit),
            get(Component::ClusterSearch),
            get(Component::Thrash),
        ]);
    }
    let out = format!("Fig. 6 — mean per-component retrieval time ({dataset})\n{}", t.render());
    println!("{out}");
    Ok(out)
}
