//! Evaluation: retrieval-quality metrics, the experiment harness that
//! regenerates every paper table/figure, and report rendering.

pub mod experiments;
pub mod harness;
pub mod recall;
pub mod report;

pub use harness::{run_workload, RunOptions, RunReport};
pub use recall::{precision_at_k, recall_at_k, QualityAccumulator, QualitySummary};
pub use report::Table;
