//! BEIR-style retrieval-quality metrics: precision@k and recall@k against
//! the workload's ground-truth qrels (paper Fig. 10).

use std::collections::HashSet;

/// recall@k: fraction of the relevant set that was retrieved.
pub fn recall_at_k(retrieved: &[u32], relevant: &[u32]) -> f64 {
    if relevant.is_empty() {
        return 1.0;
    }
    let rel: HashSet<u32> = relevant.iter().copied().collect();
    let hit = retrieved.iter().filter(|id| rel.contains(id)).count();
    hit as f64 / rel.len() as f64
}

/// precision@k: fraction of retrieved chunks that are relevant.
pub fn precision_at_k(retrieved: &[u32], relevant: &[u32]) -> f64 {
    if retrieved.is_empty() {
        return 0.0;
    }
    let rel: HashSet<u32> = relevant.iter().copied().collect();
    let hit = retrieved.iter().filter(|id| rel.contains(id)).count();
    hit as f64 / retrieved.len() as f64
}

/// Aggregated quality over a workload.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QualitySummary {
    pub recall: f64,
    pub precision: f64,
    pub queries: usize,
}

#[derive(Debug, Default)]
pub struct QualityAccumulator {
    recall_sum: f64,
    precision_sum: f64,
    n: usize,
}

impl QualityAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, retrieved: &[u32], relevant: &[u32]) {
        self.recall_sum += recall_at_k(retrieved, relevant);
        self.precision_sum += precision_at_k(retrieved, relevant);
        self.n += 1;
    }

    pub fn summary(&self) -> QualitySummary {
        let n = self.n.max(1) as f64;
        QualitySummary {
            recall: self.recall_sum / n,
            precision: self.precision_sum / n,
            queries: self.n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_retrieval() {
        assert_eq!(recall_at_k(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(precision_at_k(&[1, 2], &[1, 2]), 1.0);
    }

    #[test]
    fn partial_recall() {
        assert_eq!(recall_at_k(&[1, 9, 8], &[1, 2]), 0.5);
        assert!((precision_at_k(&[1, 9, 8], &[1, 2]) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_sets() {
        assert_eq!(recall_at_k(&[5, 6], &[1, 2]), 0.0);
        assert_eq!(precision_at_k(&[5, 6], &[1, 2]), 0.0);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(recall_at_k(&[], &[1]), 0.0);
        assert_eq!(recall_at_k(&[1], &[]), 1.0);
        assert_eq!(precision_at_k(&[], &[1]), 0.0);
    }

    #[test]
    fn recall_precision_tradeoff_with_k() {
        // Retrieving more chunks raises recall, lowers precision — the
        // Fig. 10 trade-off.
        let relevant = vec![1u32, 2];
        let k3 = &[1u32, 7, 8][..];
        let k8 = &[1u32, 7, 8, 2, 9, 10, 11, 12][..];
        assert!(recall_at_k(k8, &relevant) > recall_at_k(k3, &relevant));
        assert!(precision_at_k(k8, &relevant) < precision_at_k(k3, &relevant));
    }

    #[test]
    fn accumulator_averages() {
        let mut acc = QualityAccumulator::new();
        acc.add(&[1], &[1]);       // r=1, p=1
        acc.add(&[9], &[1, 2]);    // r=0, p=0
        let s = acc.summary();
        assert_eq!(s.queries, 2);
        assert!((s.recall - 0.5).abs() < 1e-12);
        assert!((s.precision - 0.5).abs() < 1e-12);
    }
}
