//! Experiment harness: runs a (dataset, index-config) pair over its query
//! workload and produces the numbers every paper table/figure is built
//! from. The figure benches and the `edgerag bench` CLI both drive this.

use anyhow::Result;

use crate::cache::CacheStats;
use crate::config::{DatasetProfile, IndexKind};
use crate::coordinator::builder::{BuiltDataset, SystemBuilder};
use crate::coordinator::metrics::Metrics;
use crate::eval::recall::{QualityAccumulator, QualitySummary};
use crate::json::Value;
use crate::llm::quality::generation_score;
use crate::simtime::{Component, SimDuration};

/// Everything measured from one workload run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub dataset: String,
    pub kind: IndexKind,
    pub queries: usize,

    // Latency (modeled device time).
    pub retrieval_mean: SimDuration,
    pub retrieval_p50: SimDuration,
    pub retrieval_p95: SimDuration,
    pub retrieval_p99: SimDuration,
    pub ttft_mean: SimDuration,
    pub ttft_p95: SimDuration,
    pub slo_attainment: f64,

    // Per-component means (Fig. 3 / Fig. 6 style breakdowns).
    pub mean_by_component: Vec<(&'static str, SimDuration)>,

    // Quality.
    pub quality: QualitySummary,
    pub gen_score: f64,

    // System state.
    pub resident_bytes: u64,
    pub cache: Option<CacheStats>,
    pub cache_used_bytes: u64,
    pub stored_clusters: usize,
    pub stored_bytes: u64,
    pub threshold_ms: f64,
    pub thrash_faults: u64,

    // Real coordinator time (perf accounting, not device time).
    pub wall: std::time::Duration,
}

impl RunReport {
    pub fn to_json(&self) -> Value {
        let components = Value::Object(
            self.mean_by_component
                .iter()
                .map(|(name, d)| (name.to_string(), Value::num(d.as_millis_f64())))
                .collect(),
        );
        Value::object(vec![
            ("dataset", Value::str(&self.dataset)),
            ("config", Value::str(self.kind.name())),
            ("queries", self.queries.into()),
            ("retrieval_mean_ms", self.retrieval_mean.as_millis_f64().into()),
            ("retrieval_p50_ms", self.retrieval_p50.as_millis_f64().into()),
            ("retrieval_p95_ms", self.retrieval_p95.as_millis_f64().into()),
            ("retrieval_p99_ms", self.retrieval_p99.as_millis_f64().into()),
            ("ttft_mean_ms", self.ttft_mean.as_millis_f64().into()),
            ("ttft_p95_ms", self.ttft_p95.as_millis_f64().into()),
            ("slo_attainment", self.slo_attainment.into()),
            ("mean_component_ms", components),
            ("recall", self.quality.recall.into()),
            ("precision", self.quality.precision.into()),
            ("gen_score", self.gen_score.into()),
            ("resident_bytes", self.resident_bytes.into()),
            (
                "cache_hit_rate",
                self.cache.map(|c| c.hit_rate()).unwrap_or(0.0).into(),
            ),
            ("cache_used_bytes", self.cache_used_bytes.into()),
            ("stored_clusters", self.stored_clusters.into()),
            ("stored_bytes", self.stored_bytes.into()),
            ("threshold_ms", self.threshold_ms.into()),
            ("thrash_faults", self.thrash_faults.into()),
            ("wall_ms", (self.wall.as_secs_f64() * 1e3).into()),
        ])
    }
}

/// Options for one harness run.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Evaluate only the first N queries (None = full workload).
    pub query_limit: Option<usize>,
    /// Serve (but do not record) this many leading queries first —
    /// steady-state measurement that excludes cold-start residency faults.
    pub warmup: usize,
    /// Pin the EdgeRAG caching threshold (Fig. 7 sweeps); None = adaptive.
    pub pin_threshold_ms: Option<f64>,
    /// Override nprobe.
    pub nprobe: Option<usize>,
}

/// Run one (dataset, config) pair end to end.
pub fn run_workload(
    builder: &SystemBuilder,
    built: &BuiltDataset,
    kind: IndexKind,
    opts: &RunOptions,
) -> Result<RunReport> {
    // nprobe: explicit override > per-dataset tuned value (paper §6.2).
    let sys = builder.clone_with_nprobe(Some(opts.nprobe.unwrap_or(built.profile.nprobe)));
    let pipeline = sys.pipeline(built, kind)?;
    if let Some(t) = opts.pin_threshold_ms {
        // Write lease; the VectorIndex accessor is a no-op on baselines.
        pipeline.index_mut().pin_threshold(t);
    }

    // Warmup: serve a prefix without recording (steady-state residency).
    for q in built.workload.queries.iter().take(opts.warmup) {
        pipeline.handle(&q.text)?;
    }
    pipeline.metrics().reset();

    let wall_start = std::time::Instant::now();
    let mut acc = QualityAccumulator::new();
    let mut gen_sum = 0.0;
    // Measurement uses the queries *after* the warmup prefix, so cache
    // hit rates reflect the workload's natural reuse, not replays.
    let remaining = built.workload.len().saturating_sub(opts.warmup);
    let n = opts.query_limit.unwrap_or(remaining).min(remaining);
    for q in built.workload.queries.iter().skip(opts.warmup).take(n) {
        let out = pipeline.handle(&q.text)?;
        let retrieved: Vec<u32> = out.hits.iter().map(|h| h.0).collect();
        acc.add(&retrieved, &q.relevant);
        gen_sum += generation_score(&built.corpus, &retrieved, &q.relevant, q.target_chunk);
    }
    let wall = wall_start.elapsed();

    let report = summarize(built, kind, &pipeline, acc, gen_sum, n, wall);
    Ok(report)
}

fn summarize(
    built: &BuiltDataset,
    kind: IndexKind,
    pipeline: &crate::coordinator::Engine,
    acc: QualityAccumulator,
    gen_sum: f64,
    n: usize,
    wall: std::time::Duration,
) -> RunReport {
    let slo = built.profile.slo();
    // Shared read lease: summarizing never mutates the index. All state
    // comes through the VectorIndex accessors (inert on baselines).
    let index = pipeline.index();
    let resident = index.resident_bytes();
    let (edge_cache, edge_cache_bytes, stored, stored_bytes, threshold) = (
        index.cache_stats(),
        index.cache_used_bytes(),
        index.stored_clusters(),
        index.stored_bytes(),
        index.threshold_ms(),
    );
    drop(index);
    let thrash = pipeline.metrics().counter("thrash_faults");

    let mean_by_component: Vec<(&'static str, SimDuration)> = Component::ALL
        .iter()
        .map(|&c| (c.name(), pipeline.metrics().component_mean(c)))
        .collect();

    let m: &Metrics = pipeline.metrics();
    let retrieval = m.retrieval();
    let ttft = m.ttft();
    RunReport {
        dataset: built.profile.name.clone(),
        kind,
        queries: n,
        retrieval_mean: retrieval.mean(),
        retrieval_p50: retrieval.percentile(50.0),
        retrieval_p95: retrieval.percentile(95.0),
        retrieval_p99: retrieval.percentile(99.0),
        ttft_mean: ttft.mean(),
        ttft_p95: ttft.percentile(95.0),
        slo_attainment: ttft.slo_attainment(slo),
        mean_by_component,
        quality: acc.summary(),
        gen_score: gen_sum / n.max(1) as f64,
        resident_bytes: resident,
        cache: edge_cache,
        cache_used_bytes: edge_cache_bytes,
        stored_clusters: stored,
        stored_bytes,
        threshold_ms: threshold,
        thrash_faults: thrash,
        wall,
    }
}

/// Paper §6.2: tune nprobe so the IVF-family recall normalizes to the flat
/// baseline (within `tolerance`). Evaluated over a query sample.
pub fn tune_nprobe(
    builder: &SystemBuilder,
    built: &BuiltDataset,
    tolerance: f64,
    sample: usize,
) -> Result<usize> {
    let opts = RunOptions {
        query_limit: Some(sample),
        ..Default::default()
    };
    let flat = run_workload(builder, built, IndexKind::Flat, &opts)?;
    let mut nprobe = 1;
    while nprobe <= built.centroids.len() {
        let r = run_workload(
            builder,
            built,
            IndexKind::IvfGen,
            &RunOptions {
                nprobe: Some(nprobe),
                ..opts.clone()
            },
        )?;
        if r.quality.recall >= flat.quality.recall - tolerance {
            return Ok(nprobe);
        }
        nprobe *= 2;
    }
    Ok(built.centroids.len())
}

/// Profile stats for Table 2 regeneration.
pub fn dataset_stats(built: &BuiltDataset, dim: usize) -> Value {
    let p = &built.profile;
    let unique: std::collections::HashSet<u32> = built
        .workload
        .queries
        .iter()
        .map(|q| q.target_chunk)
        .collect();
    Value::object(vec![
        ("dataset", Value::str(&p.name)),
        ("corpus_bytes", built.corpus.total_chars().into()),
        ("records", built.corpus.len().into()),
        ("embedding_bytes", p.embedding_bytes(dim).into()),
        ("unique_access", unique.len().into()),
        ("total_access", built.workload.len().into()),
        ("reuse_ratio", built.workload.reuse_ratio().into()),
        (
            "fits_in_memory",
            (p.embedding_bytes(dim)
                <= crate::config::DeviceProfile::jetson_orin_nano().mem_total_bytes
                    - crate::config::DeviceProfile::jetson_orin_nano().llm_weight_bytes)
                .into(),
        ),
    ])
}

/// Convenience: the dataset list a bench operates over (skips the large
/// profiles when `small_only`).
pub fn bench_datasets(small_only: bool) -> Vec<DatasetProfile> {
    DatasetProfile::beir_suite()
        .into_iter()
        .filter(|d| !small_only || d.n_chunks <= 16_000)
        .collect()
}
