//! Query-scoped tracing: per-stage span attribution through the fused
//! pipeline, a bounded sampling buffer, and an always-capture slow-query
//! log.
//!
//! ## Design
//!
//! The server owns a [`Tracer`] and brackets each traced request with
//! [`Tracer::begin`] / [`TraceGuard::finish`]. In between, *any* code on
//! the dispatching thread — the scheduler, the engine, the index walk
//! merge, the WAL — records spans through the free functions
//! ([`record`], [`record_since`], [`record_event`]) without holding a
//! `Tracer` reference: the in-flight trace lives in a thread-local slot
//! installed by `begin`. Work that executes on *other* threads (fused
//! kernel batches, per-shard cluster walks on the shard pool) measures
//! its own duration and returns it by value; the dispatching thread
//! attributes it back into the trace — that is how one fused batch's
//! kernel cost lands as a per-query `embed.exec` span tagged with the
//! batch width and close reason.
//!
//! ## Cost model
//!
//! * **Tracing off** (no `Tracer` ever constructed — the library
//!   default): every record site is one relaxed atomic load and a branch.
//!   No allocation, no syscall, no `Instant::now`.
//! * **Tracing on, thread not tracing** (pool workers, untraced ops): the
//!   thread-local slot is `None`; record sites return after the
//!   thread-local check.
//! * **Tracing on, thread tracing**: spans append to a `Vec` capped at
//!   [`MAX_SPANS`]; completed traces land in two fixed-capacity rings
//!   ([`RECENT_CAPACITY`], [`SLOW_CAPACITY`]). Memory is bounded by
//!   construction.
//!
//! Tracing is **purely observational**: no record site takes an index,
//! cache or scheduler lock, and nothing on any search/commit path reads
//! trace state back. The bit-equality suites pass identically with
//! tracing forced on (`EDGERAG_TEST_TRACE=1` runs that leg in CI).
//!
//! Lock hierarchy: the two ring mutexes here are leaf locks — taken only
//! in `finish`/query paths while holding no other lock, and no index or
//! scheduler code path ever takes them.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Completed traces kept in the sampling ring (oldest evicted first).
pub const RECENT_CAPACITY: usize = 256;
/// Completed traces kept in the slow-query ring.
pub const SLOW_CAPACITY: usize = 64;
/// Hard cap on spans per trace (a probe storm cannot grow a trace
/// unboundedly; later spans are dropped and counted in `dropped_spans`).
pub const MAX_SPANS: usize = 512;

/// Flipped (permanently) to true by the first [`Tracer`] constructed in
/// the process. Record sites gate on this before touching the
/// thread-local, so a library build that never constructs a `Tracer`
/// pays one relaxed load per site.
static ENABLED: AtomicBool = AtomicBool::new(false);

thread_local! {
    /// The trace in flight on this thread, installed by [`Tracer::begin`].
    static ACTIVE: RefCell<Option<ActiveTrace>> = const { RefCell::new(None) };
}

/// A span tag value. `Str` carries static labels (batch close reasons,
/// cache outcomes); `U64` carries counts and nanosecond durations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TagValue {
    U64(u64),
    Str(&'static str),
}

/// One recorded stage of a traced request. `start_ns` is the offset from
/// the trace's admission instant (the moment the request was queued), so
/// a span tree renders on one shared time axis.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub tags: Vec<(&'static str, TagValue)>,
}

/// A completed request trace.
#[derive(Debug)]
pub struct QueryTrace {
    pub id: u64,
    /// The server op traced ("query", "insert").
    pub op: &'static str,
    /// Queued-to-finished wall time.
    pub total_ns: u64,
    pub spans: Vec<Span>,
    /// Spans discarded past [`MAX_SPANS`].
    pub dropped_spans: u64,
}

struct ActiveTrace {
    id: u64,
    op: &'static str,
    /// The admission instant — span offsets and `total_ns` are measured
    /// from here, so the queue wait is inside the trace.
    queued: Instant,
    spans: Vec<Span>,
    dropped: u64,
}

/// One relaxed load: has any `Tracer` been constructed? Code that must
/// measure durations off the tracing thread (pool-side cluster walks)
/// gates its `Instant::now` calls on this.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// True when the *calling thread* has a trace in flight.
#[inline]
pub fn active() -> bool {
    enabled() && ACTIVE.with(|a| a.borrow().is_some())
}

/// Record a span that ended now with an externally measured duration
/// (batch shares, pool-side walk times). No-op unless this thread is
/// tracing.
pub fn record(name: &'static str, dur_ns: u64, tags: &[(&'static str, TagValue)]) {
    if !enabled() {
        return;
    }
    ACTIVE.with(|a| {
        if let Some(t) = a.borrow_mut().as_mut() {
            let end = t.queued.elapsed().as_nanos() as u64;
            t.push(Span {
                name,
                start_ns: end.saturating_sub(dur_ns),
                dur_ns,
                tags: tags.to_vec(),
            });
        }
    });
}

/// Record a span from `started` (captured on this thread) to now.
pub fn record_since(name: &'static str, started: Instant, tags: &[(&'static str, TagValue)]) {
    if !enabled() {
        return;
    }
    record(name, started.elapsed().as_nanos() as u64, tags);
}

/// Record a zero-duration marker (probe-snapshot rebuilds, cache
/// outcomes).
pub fn record_event(name: &'static str, tags: &[(&'static str, TagValue)]) {
    record(name, 0, tags);
}

/// `Instant::now()` only when the calling thread is tracing — the
/// zero-syscall guard for sites that bracket work with two clock reads.
#[inline]
pub fn clock() -> Option<Instant> {
    if active() {
        Some(Instant::now())
    } else {
        None
    }
}

impl ActiveTrace {
    fn push(&mut self, span: Span) {
        if self.spans.len() < MAX_SPANS {
            self.spans.push(span);
        } else {
            self.dropped += 1;
        }
    }
}

/// Aggregate counters a tracer exposes to the metrics endpoint.
#[derive(Debug, Clone, Copy, Default)]
pub struct TracerStats {
    /// Traces started.
    pub started: u64,
    /// Traces completed and captured.
    pub finished: u64,
    /// Traces that crossed the slow-query threshold.
    pub slow: u64,
}

/// The server-owned capture plane: assigns trace ids, installs the
/// thread-local slot for each traced request, and keeps the two bounded
/// rings of completed traces.
pub struct Tracer {
    /// Always-capture threshold: traces at least this long also land in
    /// the slow ring.
    slow_us: u64,
    next_id: AtomicU64,
    started: AtomicU64,
    finished: AtomicU64,
    slow_count: AtomicU64,
    recent: Mutex<VecDeque<Arc<QueryTrace>>>,
    slow: Mutex<VecDeque<Arc<QueryTrace>>>,
}

impl Tracer {
    /// Construct a tracer and (permanently, process-wide) arm the record
    /// sites. `slow_us` is the slow-query capture threshold.
    pub fn new(slow_us: u64) -> Arc<Tracer> {
        ENABLED.store(true, Ordering::Release);
        Arc::new(Tracer {
            slow_us,
            next_id: AtomicU64::new(1),
            started: AtomicU64::new(0),
            finished: AtomicU64::new(0),
            slow_count: AtomicU64::new(0),
            recent: Mutex::new(VecDeque::with_capacity(RECENT_CAPACITY)),
            slow: Mutex::new(VecDeque::with_capacity(SLOW_CAPACITY)),
        })
    }

    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_us
    }

    /// Begin tracing `op` on the calling thread. `queued` is the instant
    /// the request was admitted to the worker queue; the elapsed time to
    /// now is recorded as the `admission` span (queue wait). The returned
    /// guard must be finished (or dropped) on this same thread.
    pub fn begin(self: &Arc<Self>, op: &'static str, queued: Instant) -> TraceGuard {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.started.fetch_add(1, Ordering::Relaxed);
        let wait_ns = queued.elapsed().as_nanos() as u64;
        let mut t = ActiveTrace {
            id,
            op,
            queued,
            spans: Vec::with_capacity(16),
            dropped: 0,
        };
        t.push(Span {
            name: "admission",
            start_ns: 0,
            dur_ns: wait_ns,
            tags: Vec::new(),
        });
        ACTIVE.with(|a| *a.borrow_mut() = Some(t));
        TraceGuard {
            tracer: self.clone(),
            finished: false,
        }
    }

    /// Capture a completed trace into the rings.
    fn capture(&self, t: ActiveTrace) -> Arc<QueryTrace> {
        let total_ns = t.queued.elapsed().as_nanos() as u64;
        let trace = Arc::new(QueryTrace {
            id: t.id,
            op: t.op,
            total_ns,
            spans: t.spans,
            dropped_spans: t.dropped,
        });
        self.finished.fetch_add(1, Ordering::Relaxed);
        {
            let mut ring = self.recent.lock().unwrap();
            if ring.len() == RECENT_CAPACITY {
                ring.pop_front();
            }
            ring.push_back(trace.clone());
        }
        if total_ns / 1_000 >= self.slow_us {
            self.slow_count.fetch_add(1, Ordering::Relaxed);
            let mut ring = self.slow.lock().unwrap();
            if ring.len() == SLOW_CAPACITY {
                ring.pop_front();
            }
            ring.push_back(trace.clone());
        }
        trace
    }

    /// Completed traces in the sampling ring, oldest first.
    pub fn recent(&self) -> Vec<Arc<QueryTrace>> {
        self.recent.lock().unwrap().iter().cloned().collect()
    }

    /// Completed slow traces, oldest first.
    pub fn slow(&self) -> Vec<Arc<QueryTrace>> {
        self.slow.lock().unwrap().iter().cloned().collect()
    }

    /// Find a captured trace by id (checks both rings).
    pub fn find(&self, id: u64) -> Option<Arc<QueryTrace>> {
        if let Some(t) = self.recent.lock().unwrap().iter().find(|t| t.id == id) {
            return Some(t.clone());
        }
        self.slow.lock().unwrap().iter().find(|t| t.id == id).cloned()
    }

    pub fn stats(&self) -> TracerStats {
        TracerStats {
            started: self.started.load(Ordering::Relaxed),
            finished: self.finished.load(Ordering::Relaxed),
            slow: self.slow_count.load(Ordering::Relaxed),
        }
    }
}

/// RAII handle for one traced request. [`TraceGuard::finish`] captures
/// the trace and returns it; dropping without finishing (a dispatch
/// panic) still clears the thread-local slot so the worker thread does
/// not leak an active trace into its next request.
pub struct TraceGuard {
    tracer: Arc<Tracer>,
    finished: bool,
}

impl TraceGuard {
    /// End the trace, capture it, and return it (the server embeds the
    /// id in the response).
    pub fn finish(mut self) -> Option<Arc<QueryTrace>> {
        self.finished = true;
        let taken = ACTIVE.with(|a| a.borrow_mut().take());
        taken.map(|t| self.tracer.capture(t))
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if !self.finished {
            // Unwound mid-dispatch: still capture what was recorded so a
            // failing request's partial trace is inspectable.
            if let Some(t) = ACTIVE.with(|a| a.borrow_mut().take()) {
                self.tracer.capture(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn spans_share_the_admission_time_axis() {
        let tracer = Tracer::new(u64::MAX / 2_000);
        let queued = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let guard = tracer.begin("query", queued);
        assert!(active());
        record("work", 1_000, &[("width", TagValue::U64(4))]);
        record_event("marker", &[("kind", TagValue::Str("probe_rebuild"))]);
        let trace = guard.finish().expect("trace captured");
        assert!(!active());
        assert_eq!(trace.op, "query");
        assert_eq!(trace.spans.len(), 3);
        let admission = &trace.spans[0];
        assert_eq!(admission.name, "admission");
        assert_eq!(admission.start_ns, 0);
        assert!(admission.dur_ns >= 2_000_000, "queue wait {}", admission.dur_ns);
        let work = &trace.spans[1];
        assert_eq!(work.dur_ns, 1_000);
        assert!(work.start_ns >= admission.dur_ns);
        assert_eq!(work.tags, vec![("width", TagValue::U64(4))]);
        assert!(trace.total_ns >= admission.dur_ns);
        assert_eq!(tracer.find(trace.id).unwrap().id, trace.id);
    }

    #[test]
    fn slow_ring_captures_only_threshold_crossers() {
        let tracer = Tracer::new(1_000); // 1ms threshold
        let fast = tracer.begin("query", Instant::now());
        let fast = fast.finish().unwrap();
        let queued = Instant::now();
        std::thread::sleep(Duration::from_millis(3));
        let slow = tracer.begin("query", queued).finish().unwrap();
        let slow_ids: Vec<u64> = tracer.slow().iter().map(|t| t.id).collect();
        assert!(!slow_ids.contains(&fast.id));
        assert!(slow_ids.contains(&slow.id));
        assert_eq!(tracer.stats().finished, 2);
        assert_eq!(tracer.stats().slow, 1);
        assert_eq!(tracer.recent().len(), 2);
    }

    #[test]
    fn rings_stay_bounded() {
        let tracer = Tracer::new(0); // everything is "slow"
        for _ in 0..(RECENT_CAPACITY + 10) {
            tracer.begin("query", Instant::now()).finish().unwrap();
        }
        assert_eq!(tracer.recent().len(), RECENT_CAPACITY);
        assert_eq!(tracer.slow().len(), SLOW_CAPACITY);
    }

    #[test]
    fn untraced_thread_records_nothing() {
        let tracer = Tracer::new(1_000_000);
        record("orphan", 5, &[]);
        record_event("orphan2", &[]);
        assert!(clock().is_none());
        let t = tracer.begin("insert", Instant::now()).finish().unwrap();
        assert_eq!(t.spans.len(), 1, "only the admission span");
    }

    #[test]
    fn span_cap_bounds_trace_memory() {
        let tracer = Tracer::new(u64::MAX / 2_000);
        let guard = tracer.begin("query", Instant::now());
        for _ in 0..(MAX_SPANS + 50) {
            record("flood", 1, &[]);
        }
        let t = guard.finish().unwrap();
        assert_eq!(t.spans.len(), MAX_SPANS);
        assert_eq!(t.dropped_spans, 51); // 50 floods + admission pushed first
    }
}
