//! Deterministic hashed tokenizer — the rust mirror of
//! `python/compile/tokenizer.py`. The two must agree bit-for-bit: rust
//! tokenizes on the serving path, python at kernel-validation time.
//! Cross-checked by `tests/golden/tokenizer.json`.

pub const VOCAB: usize = 4096;
pub const PAD_ID: i32 = 0;
pub const CLS_ID: i32 = 1;
pub const SEQ_LEN: usize = 64;

const FNV_OFFSET: u32 = 0x811C_9DC5;
const FNV_PRIME: u32 = 0x0100_0193;

/// FNV-1a 32-bit hash.
pub fn fnv1a32(data: &[u8]) -> u32 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Lowercased alphanumeric-run words (ascii-only alnum, like the python
/// side's `ch.isascii() and ch.isalnum()`).
pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        let lc = ch.to_ascii_lowercase();
        if lc.is_ascii_alphanumeric() {
            cur.push(lc);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

pub fn token_id(word: &str) -> i32 {
    2 + (fnv1a32(word.as_bytes()) % (VOCAB as u32 - 2)) as i32
}

pub fn token_ids(text: &str) -> Vec<i32> {
    words(text).iter().map(|w| token_id(w)).collect()
}

/// Bag-of-tokens count features, f32[VOCAB] — input to the projection
/// embedder. Raw counts are exact in f32, so python/rust agree exactly.
pub fn features(text: &str) -> Vec<f32> {
    let mut f = vec![0.0f32; VOCAB];
    for tid in token_ids(text) {
        f[tid as usize] += 1.0;
    }
    f
}

/// Accumulate features for `text` into an existing buffer (zero-alloc path
/// for batched embedding generation).
pub fn features_into(text: &str, out: &mut [f32]) {
    debug_assert_eq!(out.len(), VOCAB);
    out.fill(0.0);
    for tid in token_ids(text) {
        out[tid as usize] += 1.0;
    }
}

/// `[CLS] + ids` padded/truncated to `seq_len` → (ids, mask).
pub fn sequence(text: &str, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let mut ids = vec![PAD_ID; seq_len];
    let mut mask = vec![0.0f32; seq_len];
    ids[0] = CLS_ID;
    mask[0] = 1.0;
    for (i, tid) in token_ids(text).into_iter().take(seq_len - 1).enumerate() {
        ids[i + 1] = tid;
        mask[i + 1] = 1.0;
    }
    (ids, mask)
}

/// Token count of a text under this tokenizer (used for prompt budgeting).
pub fn count_tokens(text: &str) -> usize {
    words(text).len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_values() {
        assert_eq!(fnv1a32(b""), 0x811C_9DC5);
        assert_eq!(fnv1a32(b"a"), 0xE40C_292C);
        assert_eq!(fnv1a32(b"foobar"), 0xBF9C_F968);
    }

    #[test]
    fn words_split_and_lowercase() {
        assert_eq!(words("Hello, World!"), vec!["hello", "world"]);
        assert_eq!(words("  a-b_c  "), vec!["a", "b", "c"]);
        assert_eq!(words(""), Vec::<String>::new());
    }

    #[test]
    fn ids_in_range() {
        for id in token_ids("the quick brown fox 123") {
            assert!((2..VOCAB as i32).contains(&id));
        }
    }

    #[test]
    fn features_sum_to_token_count() {
        let text = "repeated repeated words words words";
        let f = features(text);
        assert_eq!(f.iter().sum::<f32>(), 5.0);
        let ids = token_ids(text);
        assert_eq!(f[ids[0] as usize], 2.0);
        assert_eq!(f[ids[2] as usize], 3.0);
    }

    #[test]
    fn features_into_matches_features() {
        let mut buf = vec![7.0f32; VOCAB];
        features_into("alpha beta gamma", &mut buf);
        assert_eq!(buf, features("alpha beta gamma"));
    }

    #[test]
    fn sequence_layout_and_truncation() {
        let (ids, mask) = sequence("hello world", 8);
        assert_eq!(ids[0], CLS_ID);
        assert_eq!(&mask[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(mask[3..].iter().sum::<f32>(), 0.0);

        let long: String = (0..100).map(|i| format!("w{i} ")).collect();
        let (ids, mask) = sequence(&long, 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(mask.iter().sum::<f32>(), 16.0);
    }

    #[test]
    fn case_insensitive() {
        assert_eq!(token_ids("EdgeRAG Rules"), token_ids("edgerag rules"));
    }
}
