//! Embedding generation service: text → unit vectors through the compiled
//! PJRT executables. This is the compute EdgeRAG schedules, prices, and
//! caches — online embedding generation (paper §3.2/§4) all flows through
//! [`Embedder::embed_texts`].

pub mod tokenizer;

use anyhow::Result;

use crate::runtime::{ComputeHandle, Tensor};
use crate::vecmath::EmbeddingMatrix;

/// Which Layer-2 model embeds text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmbedderBackend {
    /// Hashed bag-of-tokens × learned projection (Pallas `projection`
    /// kernel). Fast path; used for the large-scale experiments.
    Projection,
    /// 4-layer transformer encoder (Pallas `attention` kernel), gte-style
    /// mean-pool + L2 norm. Used by the e2e example / quickstart.
    Transformer,
}

impl EmbedderBackend {
    pub fn name(self) -> &'static str {
        match self {
            EmbedderBackend::Projection => "projection",
            EmbedderBackend::Transformer => "transformer",
        }
    }
}

/// Embedding service over the compute executor, with shape-bucketed
/// batching.
#[derive(Clone)]
pub struct Embedder {
    compute: ComputeHandle,
    backend: EmbedderBackend,
    proj_batches: Vec<usize>,
    enc_batches: Vec<usize>,
    vocab: usize,
    enc_seq: usize,
    dim: usize,
}

impl Embedder {
    pub fn new(compute: ComputeHandle, backend: EmbedderBackend) -> Self {
        let m = compute.manifest();
        Embedder {
            proj_batches: m.proj_batches.clone(),
            enc_batches: m.enc_batches.clone(),
            vocab: m.vocab,
            enc_seq: m.enc_seq,
            dim: m.dim,
            compute,
            backend,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn backend(&self) -> EmbedderBackend {
        self.backend
    }

    /// Embed a batch of texts into an `EmbeddingMatrix` (one unit vector
    /// per text, row order preserved). Internally splits into the largest
    /// compiled batch bucket and pads the remainder.
    pub fn embed_texts(&self, texts: &[&str]) -> Result<EmbeddingMatrix> {
        let mut out = EmbeddingMatrix::with_capacity(self.dim, texts.len());
        match self.backend {
            EmbedderBackend::Projection => self.embed_projection(texts, &mut out)?,
            EmbedderBackend::Transformer => self.embed_transformer(texts, &mut out)?,
        }
        Ok(out)
    }

    pub fn embed_one(&self, text: &str) -> Result<Vec<f32>> {
        let m = self.embed_texts(&[text])?;
        Ok(m.row(0).to_vec())
    }

    /// Largest compiled bucket ≤ remaining, or the smallest bucket
    /// (padding) when remaining is below every bucket.
    fn pick_bucket(buckets: &[usize], remaining: usize) -> usize {
        buckets
            .iter()
            .copied()
            .filter(|&b| b <= remaining)
            .max()
            .unwrap_or_else(|| buckets.iter().copied().min().unwrap())
    }

    fn embed_projection(&self, texts: &[&str], out: &mut EmbeddingMatrix) -> Result<()> {
        let mut i = 0;
        while i < texts.len() {
            let b = Self::pick_bucket(&self.proj_batches, texts.len() - i);
            let take = b.min(texts.len() - i);
            let mut feats = vec![0.0f32; b * self.vocab];
            for (j, text) in texts[i..i + take].iter().enumerate() {
                tokenizer::features_into(
                    text,
                    &mut feats[j * self.vocab..(j + 1) * self.vocab],
                );
            }
            let res = self.compute.run(
                &format!("proj_{b}"),
                vec![Tensor::F32(feats, vec![b, self.vocab])],
            )?;
            for j in 0..take {
                out.push(&res[0][j * self.dim..(j + 1) * self.dim]);
            }
            i += take;
        }
        Ok(())
    }

    fn embed_transformer(&self, texts: &[&str], out: &mut EmbeddingMatrix) -> Result<()> {
        let mut i = 0;
        while i < texts.len() {
            let b = Self::pick_bucket(&self.enc_batches, texts.len() - i);
            let take = b.min(texts.len() - i);
            let mut ids = vec![0i32; b * self.enc_seq];
            let mut mask = vec![0.0f32; b * self.enc_seq];
            for (j, text) in texts[i..i + take].iter().enumerate() {
                let (tids, tmask) = tokenizer::sequence(text, self.enc_seq);
                ids[j * self.enc_seq..(j + 1) * self.enc_seq].copy_from_slice(&tids);
                mask[j * self.enc_seq..(j + 1) * self.enc_seq].copy_from_slice(&tmask);
            }
            // Padding rows still flow through the encoder; give them a
            // valid CLS so layernorm/softmax see sane inputs, then drop.
            for j in take..b {
                ids[j * self.enc_seq] = tokenizer::CLS_ID;
                mask[j * self.enc_seq] = 1.0;
            }
            let res = self.compute.run(
                &format!("enc_{b}"),
                vec![
                    Tensor::I32(ids, vec![b, self.enc_seq]),
                    Tensor::F32(mask, vec![b, self.enc_seq]),
                ],
            )?;
            for j in 0..take {
                out.push(&res[0][j * self.dim..(j + 1) * self.dim]);
            }
            i += take;
        }
        Ok(())
    }
}
