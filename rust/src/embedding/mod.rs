//! Embedding generation service: text → unit vectors through the compiled
//! PJRT executables. This is the compute EdgeRAG schedules, prices, and
//! caches — online embedding generation (paper §3.2/§4) all flows through
//! [`Embedder::embed_texts`].

pub mod tokenizer;

use anyhow::Result;

use crate::runtime::{ComputeHandle, Tensor};
use crate::vecmath::EmbeddingMatrix;

/// Which Layer-2 model embeds text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmbedderBackend {
    /// Hashed bag-of-tokens × learned projection (Pallas `projection`
    /// kernel). Fast path; used for the large-scale experiments.
    Projection,
    /// 4-layer transformer encoder (Pallas `attention` kernel), gte-style
    /// mean-pool + L2 norm. Used by the e2e example / quickstart.
    Transformer,
}

impl EmbedderBackend {
    pub fn name(self) -> &'static str {
        match self {
            EmbedderBackend::Projection => "projection",
            EmbedderBackend::Transformer => "transformer",
        }
    }
}

/// Embedding service over the compute executor, with shape-bucketed
/// batching.
#[derive(Clone)]
pub struct Embedder {
    compute: ComputeHandle,
    backend: EmbedderBackend,
    proj_batches: Vec<usize>,
    enc_batches: Vec<usize>,
    vocab: usize,
    enc_seq: usize,
    dim: usize,
}

impl Embedder {
    pub fn new(compute: ComputeHandle, backend: EmbedderBackend) -> Self {
        let m = compute.manifest();
        Embedder {
            proj_batches: m.proj_batches.clone(),
            enc_batches: m.enc_batches.clone(),
            vocab: m.vocab,
            enc_seq: m.enc_seq,
            dim: m.dim,
            compute,
            backend,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn backend(&self) -> EmbedderBackend {
        self.backend
    }

    /// The underlying compute service (shared with scorers and the batch
    /// scheduler's stages).
    pub fn compute(&self) -> &ComputeHandle {
        &self.compute
    }

    /// Embed a batch of texts into an `EmbeddingMatrix` (one unit vector
    /// per text, row order preserved). Internally splits into the largest
    /// compiled batch bucket; a small remainder runs through the smallest
    /// bucket that covers it one sub-batch at a time.
    pub fn embed_texts(&self, texts: &[&str]) -> Result<EmbeddingMatrix> {
        self.embed_with(texts, false)
    }

    fn embed_with(&self, texts: &[&str], fuse: bool) -> Result<EmbeddingMatrix> {
        let mut out = EmbeddingMatrix::with_capacity(self.dim, texts.len());
        match self.backend {
            EmbedderBackend::Projection => self.embed_projection(texts, fuse, &mut out)?,
            EmbedderBackend::Transformer => self.embed_transformer(texts, fuse, &mut out)?,
        }
        Ok(out)
    }

    pub fn embed_one(&self, text: &str) -> Result<Vec<f32>> {
        let m = self.embed_texts(&[text])?;
        Ok(m.row(0).to_vec())
    }

    /// Embed several independent requests' texts in **one fused pass** —
    /// the cross-query batched entry point ([`crate::sched`]'s embed
    /// stage): all texts are concatenated, run through the shape-bucketed
    /// kernels together (so two concurrent single-text requests share one
    /// `proj_32`/`enc_8` call instead of issuing two batch-1 calls), and
    /// the rows are split back per request.
    ///
    /// Bit-equivalence: every embedding kernel computes its rows
    /// independently, so each request's matrix is identical to what
    /// [`Embedder::embed_texts`] returns for it alone.
    pub fn embed_requests(&self, requests: &[Vec<String>]) -> Result<Vec<EmbeddingMatrix>> {
        let refs: Vec<&str> = requests
            .iter()
            .flat_map(|r| r.iter().map(|s| s.as_str()))
            .collect();
        let all = self.embed_with(&refs, true)?;
        let mut out = Vec::with_capacity(requests.len());
        let mut row = 0;
        for req in requests {
            let mut m = EmbeddingMatrix::with_capacity(self.dim, req.len());
            for _ in 0..req.len() {
                m.push(all.row(row));
                row += 1;
            }
            out.push(m);
        }
        Ok(out)
    }

    /// The widest compiled batch bucket of the active backend — the
    /// natural width of a cross-query embed batch.
    pub fn max_batch(&self) -> usize {
        let buckets = match self.backend {
            EmbedderBackend::Projection => &self.proj_batches,
            EmbedderBackend::Transformer => &self.enc_batches,
        };
        buckets.iter().copied().max().unwrap_or(1)
    }

    /// Bucket policy. Unfused (the historical path): largest compiled
    /// bucket ≤ remaining, or the smallest bucket (padding) when
    /// remaining is below every bucket — minimal padded compute, one
    /// call per sub-batch. Fused (the cross-query batch scheduler):
    /// smallest bucket ≥ remaining — **one** padded kernel dispatch
    /// covers the whole batch, which is the point of coalescing.
    fn pick_bucket(buckets: &[usize], remaining: usize, fuse: bool) -> usize {
        if fuse {
            if let Some(b) = buckets.iter().copied().filter(|&b| b >= remaining).min() {
                return b;
            }
        }
        buckets
            .iter()
            .copied()
            .filter(|&b| b <= remaining)
            .max()
            .unwrap_or_else(|| buckets.iter().copied().min().unwrap())
    }

    fn embed_projection(
        &self,
        texts: &[&str],
        fuse: bool,
        out: &mut EmbeddingMatrix,
    ) -> Result<()> {
        let mut i = 0;
        while i < texts.len() {
            let b = Self::pick_bucket(&self.proj_batches, texts.len() - i, fuse);
            let take = b.min(texts.len() - i);
            let mut feats = vec![0.0f32; b * self.vocab];
            for (j, text) in texts[i..i + take].iter().enumerate() {
                tokenizer::features_into(
                    text,
                    &mut feats[j * self.vocab..(j + 1) * self.vocab],
                );
            }
            let res = self.compute.run(
                &format!("proj_{b}"),
                vec![Tensor::F32(feats, vec![b, self.vocab])],
            )?;
            for j in 0..take {
                out.push(&res[0][j * self.dim..(j + 1) * self.dim]);
            }
            i += take;
        }
        Ok(())
    }

    fn embed_transformer(
        &self,
        texts: &[&str],
        fuse: bool,
        out: &mut EmbeddingMatrix,
    ) -> Result<()> {
        let mut i = 0;
        while i < texts.len() {
            let b = Self::pick_bucket(&self.enc_batches, texts.len() - i, fuse);
            let take = b.min(texts.len() - i);
            let mut ids = vec![0i32; b * self.enc_seq];
            let mut mask = vec![0.0f32; b * self.enc_seq];
            for (j, text) in texts[i..i + take].iter().enumerate() {
                let (tids, tmask) = tokenizer::sequence(text, self.enc_seq);
                ids[j * self.enc_seq..(j + 1) * self.enc_seq].copy_from_slice(&tids);
                mask[j * self.enc_seq..(j + 1) * self.enc_seq].copy_from_slice(&tmask);
            }
            // Padding rows still flow through the encoder; give them a
            // valid CLS so layernorm/softmax see sane inputs, then drop.
            for j in take..b {
                ids[j * self.enc_seq] = tokenizer::CLS_ID;
                mask[j * self.enc_seq] = 1.0;
            }
            let res = self.compute.run(
                &format!("enc_{b}"),
                vec![
                    Tensor::I32(ids, vec![b, self.enc_seq]),
                    Tensor::F32(mask, vec![b, self.enc_seq]),
                ],
            )?;
            for j in 0..take {
                out.push(&res[0][j * self.dim..(j + 1) * self.dim]);
            }
            i += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::shared_compute;

    #[test]
    fn fused_requests_match_individual_embeds() {
        // Cross-query coalescing must be invisible in the numerics: every
        // request's rows are bit-identical to embedding it alone.
        for backend in [EmbedderBackend::Projection, EmbedderBackend::Transformer] {
            let e = Embedder::new(shared_compute(), backend);
            let requests: Vec<Vec<String>> = vec![
                vec!["a lone query about topic zero t0w1".into()],
                vec!["another concurrent query t1w2 t1w3".into()],
                vec![
                    "cluster re-embed row one t2w1".into(),
                    "cluster re-embed row two t2w2".into(),
                    "cluster re-embed row three t2w3".into(),
                ],
            ];
            let fused = e.embed_requests(&requests).unwrap();
            assert_eq!(fused.len(), requests.len());
            for (req, got) in requests.iter().zip(&fused) {
                let refs: Vec<&str> = req.iter().map(|s| s.as_str()).collect();
                let solo = e.embed_texts(&refs).unwrap();
                assert_eq!(got.data, solo.data, "{} diverged", backend.name());
            }
        }
    }

    #[test]
    fn max_batch_reflects_backend_buckets() {
        let p = Embedder::new(shared_compute(), EmbedderBackend::Projection);
        let t = Embedder::new(shared_compute(), EmbedderBackend::Transformer);
        assert!(p.max_batch() >= 2, "projection fuses multiple requests");
        assert!(t.max_batch() >= 2, "encoder fuses multiple requests");
    }
}
