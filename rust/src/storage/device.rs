//! Flash storage device model (SD UHS-I card, paper Table 3).
//!
//! Tracks modeled access costs and simple utilization counters. The cost
//! model lives in [`DeviceProfile`]; this wrapper adds the accounting the
//! experiment harness reports (bytes read, reads issued, time spent) and
//! the distinction between scattered reads (page-ins of pruned index
//! state, random-IO-rate bound) and contiguous blob reads (precomputed
//! tail-cluster embeddings, sequential-rate bound).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::DeviceProfile;
use crate::simtime::SimDuration;

#[derive(Debug, Default)]
pub struct StorageStats {
    pub reads: AtomicU64,
    pub bytes_read: AtomicU64,
    pub time_ns: AtomicU64,
}

/// The modeled flash device.
#[derive(Debug)]
pub struct StorageDevice {
    profile: DeviceProfile,
    stats: StorageStats,
}

impl StorageDevice {
    pub fn new(profile: DeviceProfile) -> Self {
        StorageDevice {
            profile,
            stats: StorageStats::default(),
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Cost of reading `bytes` laid out contiguously (precomputed blobs).
    pub fn read_contiguous(&self, bytes: u64) -> SimDuration {
        self.record(bytes, self.profile.storage_read_cost(bytes, true))
    }

    /// Cost of reading `bytes` scattered across the device (page-ins of a
    /// paged-out in-memory structure; FAISS-style mmap thrash).
    pub fn read_scattered(&self, bytes: u64) -> SimDuration {
        self.record(bytes, self.profile.storage_read_cost(bytes, false))
    }

    fn record(&self, bytes: u64, d: SimDuration) -> SimDuration {
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.stats.time_ns.fetch_add(d.as_nanos(), Ordering::Relaxed);
        d
    }

    pub fn reads(&self) -> u64 {
        self.stats.reads.load(Ordering::Relaxed)
    }

    pub fn bytes_read(&self) -> u64 {
        self.stats.bytes_read.load(Ordering::Relaxed)
    }

    pub fn total_time(&self) -> SimDuration {
        SimDuration::from_nanos(self.stats.time_ns.load(Ordering::Relaxed))
    }

    pub fn reset_stats(&self) {
        self.stats.reads.store(0, Ordering::Relaxed);
        self.stats.bytes_read.store(0, Ordering::Relaxed);
        self.stats.time_ns.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> StorageDevice {
        StorageDevice::new(DeviceProfile::jetson_orin_nano())
    }

    #[test]
    fn contiguous_faster_than_scattered() {
        // Contiguous blobs stream; scattered reads pay random-IO rates.
        // This asymmetry is why EdgeRAG persists only large tail clusters
        // as contiguous blobs (paper §4.1).
        let d = dev();
        for bytes in [64u64 << 10, 256 << 10, 2 << 20] {
            assert!(d.read_contiguous(bytes) < d.read_scattered(bytes));
        }
    }

    #[test]
    fn stats_accumulate() {
        let d = dev();
        d.read_contiguous(1000);
        d.read_scattered(500);
        assert_eq!(d.reads(), 2);
        assert_eq!(d.bytes_read(), 1500);
        assert!(d.total_time() > SimDuration::ZERO);
        d.reset_stats();
        assert_eq!(d.reads(), 0);
    }

    #[test]
    fn cost_monotonic_in_bytes() {
        let d = dev();
        let mut last = SimDuration::ZERO;
        for kb in [4u64, 64, 256, 1024, 4096] {
            let c = d.read_contiguous(kb << 10);
            assert!(c > last);
            last = c;
        }
    }
}
