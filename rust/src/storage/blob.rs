//! On-disk blob store for precomputed cluster embeddings.
//!
//! EdgeRAG's selective index storage (paper §4.1) persists the embeddings
//! of heavy tail clusters at indexing time. This store writes real files
//! (one per cluster, contiguous f32 rows) so state survives restarts;
//! retrieval-time read *latency* is modeled by the
//! [`StorageDevice`](super::StorageDevice) since this testbed's disk is
//! not an SD card.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::vecmath::EmbeddingMatrix;

/// Persistent store of per-cluster embedding blobs.
#[derive(Debug)]
pub struct BlobStore {
    dir: PathBuf,
    dim: usize,
    /// Blob sizes by cluster id (index kept in memory, like the paper's
    /// first-level references to stored second-level indexes).
    sizes: Mutex<HashMap<u32, u64>>,
    /// Fault injection (crash-consistency tests): fail the next N `put`
    /// calls. An injected failure returns `Err` *before* touching the
    /// file or the size index — the clean abort the structural-op
    /// composition layer is designed around.
    fail_puts: AtomicU32,
    /// Fault injection: fail the next N `remove` calls that would
    /// actually delete a blob (removes of absent blobs don't consume a
    /// charge).
    fail_removes: AtomicU32,
}

impl BlobStore {
    /// Open (creating if needed) a blob store rooted at `dir`.
    pub fn open(dir: &Path, dim: usize) -> Result<BlobStore> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating blob dir {}", dir.display()))?;
        let mut sizes = HashMap::new();
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(id) = name
                .strip_prefix("cluster_")
                .and_then(|s| s.strip_suffix(".emb"))
                .and_then(|s| s.parse::<u32>().ok())
            {
                sizes.insert(id, entry.metadata()?.len());
            }
        }
        Ok(BlobStore {
            dir: dir.to_path_buf(),
            dim,
            sizes: Mutex::new(sizes),
            fail_puts: AtomicU32::new(0),
            fail_removes: AtomicU32::new(0),
        })
    }

    /// Arm fault injection: the next `n` [`BlobStore::put`] calls fail
    /// cleanly (no file or index mutation). Test hook for the
    /// crash-consistency suites (`rust/tests/merge_faults.rs`).
    pub fn inject_put_failures(&self, n: u32) {
        self.fail_puts.store(n, Ordering::SeqCst);
    }

    /// Arm fault injection: the next `n` effective [`BlobStore::remove`]
    /// calls fail cleanly.
    pub fn inject_remove_failures(&self, n: u32) {
        self.fail_removes.store(n, Ordering::SeqCst);
    }

    /// Consume one charge from an armed fault counter.
    fn take_fault(counter: &AtomicU32) -> bool {
        counter
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok()
    }

    fn path(&self, cluster: u32) -> PathBuf {
        self.dir.join(format!("cluster_{cluster}.emb"))
    }

    pub fn contains(&self, cluster: u32) -> bool {
        self.sizes.lock().unwrap().contains_key(&cluster)
    }

    pub fn len(&self) -> usize {
        self.sizes.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes of a stored blob (None if absent).
    pub fn blob_bytes(&self, cluster: u32) -> Option<u64> {
        self.sizes.lock().unwrap().get(&cluster).copied()
    }

    /// Total bytes across all stored blobs.
    pub fn total_bytes(&self) -> u64 {
        self.sizes.lock().unwrap().values().sum()
    }

    /// Ids of every stored cluster, sorted (the rebalancer's
    /// orphaned-blob invariant check walks this).
    pub fn cluster_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.sizes.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Persist a cluster's embeddings as one contiguous blob.
    pub fn put(&self, cluster: u32, emb: &EmbeddingMatrix) -> Result<()> {
        if emb.dim != self.dim {
            bail!("blob dim {} != store dim {}", emb.dim, self.dim);
        }
        if Self::take_fault(&self.fail_puts) {
            bail!("injected blob fault: put(cluster {cluster})");
        }
        let mut bytes = Vec::with_capacity(emb.data.len() * 4);
        for v in &emb.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let path = self.path(cluster);
        fs::write(&path, &bytes)
            .with_context(|| format!("writing blob {}", path.display()))?;
        self.sizes
            .lock()
            .unwrap()
            .insert(cluster, bytes.len() as u64);
        Ok(())
    }

    /// Load a cluster's embeddings.
    pub fn get(&self, cluster: u32) -> Result<EmbeddingMatrix> {
        let path = self.path(cluster);
        let bytes =
            fs::read(&path).with_context(|| format!("reading blob {}", path.display()))?;
        if bytes.len() % (self.dim * 4) != 0 {
            bail!(
                "blob {} has {} bytes, not a multiple of row size {}",
                path.display(),
                bytes.len(),
                self.dim * 4
            );
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(EmbeddingMatrix {
            dim: self.dim,
            data,
        })
    }

    /// Remove a blob (EdgeRAG removal path, §5.4).
    pub fn remove(&self, cluster: u32) -> Result<()> {
        if self.contains(cluster) && Self::take_fault(&self.fail_removes) {
            bail!("injected blob fault: remove(cluster {cluster})");
        }
        let path = self.path(cluster);
        if path.exists() {
            fs::remove_file(&path)?;
        }
        self.sizes.lock().unwrap().remove(&cluster);
        Ok(())
    }

    /// Delete everything (rebuild path).
    pub fn clear(&self) -> Result<()> {
        let ids: Vec<u32> = self.sizes.lock().unwrap().keys().copied().collect();
        for id in ids {
            self.remove(id)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "edgerag-blob-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample(dim: usize, n: usize) -> EmbeddingMatrix {
        let mut m = EmbeddingMatrix::new(dim);
        for i in 0..n {
            let row: Vec<f32> = (0..dim).map(|j| (i * dim + j) as f32 * 0.5).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn put_get_roundtrip() {
        let dir = tmpdir("roundtrip");
        let store = BlobStore::open(&dir, 8).unwrap();
        let emb = sample(8, 5);
        store.put(3, &emb).unwrap();
        assert!(store.contains(3));
        assert_eq!(store.blob_bytes(3), Some(5 * 8 * 4));
        let back = store.get(3).unwrap();
        assert_eq!(back.data, emb.data);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_recovers_index() {
        let dir = tmpdir("reopen");
        {
            let store = BlobStore::open(&dir, 4).unwrap();
            store.put(1, &sample(4, 2)).unwrap();
            store.put(9, &sample(4, 7)).unwrap();
        }
        let store = BlobStore::open(&dir, 4).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.contains(1) && store.contains(9));
        assert_eq!(store.get(9).unwrap().len(), 7);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn remove_and_clear() {
        let dir = tmpdir("remove");
        let store = BlobStore::open(&dir, 4).unwrap();
        store.put(1, &sample(4, 1)).unwrap();
        store.put(2, &sample(4, 2)).unwrap();
        store.remove(1).unwrap();
        assert!(!store.contains(1));
        assert!(store.get(1).is_err());
        store.clear().unwrap();
        assert!(store.is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dim_mismatch_rejected() {
        let dir = tmpdir("dim");
        let store = BlobStore::open(&dir, 4).unwrap();
        assert!(store.put(0, &sample(8, 1)).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn total_bytes_tracks_blobs() {
        let dir = tmpdir("total");
        let store = BlobStore::open(&dir, 4).unwrap();
        store.put(1, &sample(4, 3)).unwrap();
        store.put(2, &sample(4, 5)).unwrap();
        assert_eq!(store.total_bytes(), (3 + 5) * 4 * 4);
        fs::remove_dir_all(&dir).unwrap();
    }
}
