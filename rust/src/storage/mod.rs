//! Storage substrate: the modeled flash device, the real on-disk blob
//! store for precomputed cluster embeddings, the structural write-ahead
//! log, and the memory-budget / thrash model.

pub mod blob;
pub mod device;
pub mod memory;
pub mod wal;

pub use blob::BlobStore;
pub use device::StorageDevice;
pub use memory::{MemoryModel, Region, PAGE_BYTES};
pub use wal::{WalActivity, WalOp, WriteAheadLog};
