//! Structural write-ahead log with snapshot rotation.
//!
//! Blobs persist, but the structural state the online index accumulates —
//! cluster membership, split lineage, ownership placement, pinned
//! thresholds — was memory-only: every restart was a full rebuild. This
//! module logs each structural op as a length-prefixed, checksummed
//! record *before* its irreversible in-memory mutation (the same
//! fallible-first ordering discipline the blob transitions follow), so
//! startup can reconstruct the exact pre-crash index by replaying the
//! log into a fresh build.
//!
//! ## Record format
//!
//! The log is a flat sequence of frames:
//!
//! ```text
//!   len:  u32 LE   payload byte length
//!   seq:  u64 LE   record sequence number (1-based, strictly +1)
//!   hash: u64 LE   FNV-1a 64 over seq (LE bytes) ‖ payload
//!   payload        WalOp encoding (tag byte + LE fields)
//! ```
//!
//! A crash can tear the final frame (short write) or leave trailing
//! garbage; the scanner stops at the first frame whose length, checksum,
//! sequence continuity or payload decoding fails and truncates the file
//! back to the last good frame — a torn tail costs at most the op that
//! was mid-append, never an earlier record.
//!
//! ## Replayable vs derived records
//!
//! Two record classes share the log:
//!
//! * **Replayable** — [`WalOp::Insert`], [`WalOp::Remove`],
//!   [`WalOp::Migrate`], [`WalOp::PinThreshold`]: the externally driven
//!   ops. Recovery replays exactly these, in sequence order, through the
//!   index's normal public update paths.
//! * **Derived** — [`WalOp::Split`], [`WalOp::Merge`]: structure the
//!   index derives deterministically *from* the replayable ops (a split
//!   when an insert overflows a cluster, a merge when a removal drains
//!   one). They are recorded as an audit trail of the derived lineage,
//!   and recovery **skips** them: replaying the parent op re-derives the
//!   same split/merge bit-for-bit, and cluster ids are allocated densely
//!   in creation order on both sides. This is also what makes a torn
//!   tail safe: losing a trailing derived record loses nothing, because
//!   its parent record re-creates it.
//!
//! ## Snapshot rotation
//!
//! Naively the log grows forever, so every `snapshot_interval` appends
//! the log **rotates**: the current snapshot's records and the live log
//! records are consolidated into a fresh snapshot file (magic, covering
//! watermark, then the same frame format), written to a temp file,
//! fsynced, atomically renamed over the old snapshot, and only then is
//! the log truncated. The snapshot is a *consolidated op archive*, not a
//! state dump — cluster-id allocation depends on the full op history
//! (splits and merges are order-dependent), so replaying the archive is
//! the only representation that keeps recovery bit-identical to the
//! sequential oracle. Crash points are each individually safe:
//!
//! * mid-snapshot (temp written, not renamed): recovery ignores and
//!   deletes the temp file; the old snapshot + full log still hold every
//!   record;
//! * between rename and truncation: the log's records are all covered by
//!   the new snapshot's watermark; recovery skips them by `seq` and
//!   finishes the interrupted truncation.
//!
//! ## Durability boundary
//!
//! Appends are unbuffered writes (durable against process death the
//! moment `append` returns); the file is fsynced on rotation and on
//! [`WriteAheadLog::checkpoint`] (the server's clean-shutdown flush), so
//! power-loss durability is bounded by the snapshot interval. An append
//! error must abort the structural op before any in-memory mutation; the
//! record may still be on disk, in which case replay applies it — the
//! recovery invariant is "fresh build + replay of the surviving log",
//! not "the pre-crash memory image".

use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::trace;

/// Log file name inside the WAL directory.
const LOG_FILE: &str = "wal.log";
/// Snapshot (consolidated op archive) file name.
const SNAPSHOT_FILE: &str = "wal.snapshot";
/// Temp file the snapshot is staged in before the atomic rename.
const SNAPSHOT_TMP: &str = "wal.snapshot.tmp";
/// Snapshot header magic (version-tagged).
const SNAPSHOT_MAGIC: &[u8; 8] = b"ERAGWAL1";
/// Frame header: len u32 + seq u64 + hash u64.
const FRAME_HEADER: usize = 4 + 8 + 8;
/// Sanity cap on a single record's payload (a frame whose length field
/// exceeds this is treated as torn, not as a 4 GB allocation request).
const MAX_PAYLOAD: usize = 1 << 28;

/// One logged structural op. `Insert` carries the full chunk payload
/// (text + embedding) so replay needs no embedder and no text store —
/// the log alone, applied to the deterministic dataset build, is the
/// index.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    /// Online chunk insertion (replayable).
    Insert { id: u32, text: String, emb: Vec<f32> },
    /// Online chunk removal (replayable).
    Remove { id: u32 },
    /// Rebalancer migration of a global cluster to a destination shard
    /// (replayable — placement is externally driven, so replay must not
    /// re-plan it; it re-applies the recorded moves).
    Migrate { global: u32, dest: u32 },
    /// Threshold pin (replayable; adaptive threshold *state* is not
    /// logged — recovery restarts adaptation, matching a fresh build).
    PinThreshold { ms: f64 },
    /// Derived: an insert split `cluster`, creating `new_cluster`
    /// (audit record; replay re-derives it from the parent insert).
    Split { cluster: u32, new_cluster: u32 },
    /// Derived: drained `source` was absorbed into `victim` (audit
    /// record; replay re-derives it from the parent removal).
    Merge { source: u32, victim: u32 },
}

impl WalOp {
    /// True for the ops recovery replays (the others are derived audit
    /// records — see the module docs).
    pub fn is_replayable(&self) -> bool {
        !matches!(self, WalOp::Split { .. } | WalOp::Merge { .. })
    }

    /// Serialize to the payload encoding (tag byte + LE fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            WalOp::Insert { id, text, emb } => {
                b.push(0);
                b.extend_from_slice(&id.to_le_bytes());
                b.extend_from_slice(&(text.len() as u32).to_le_bytes());
                b.extend_from_slice(text.as_bytes());
                b.extend_from_slice(&(emb.len() as u32).to_le_bytes());
                for v in emb {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            WalOp::Remove { id } => {
                b.push(1);
                b.extend_from_slice(&id.to_le_bytes());
            }
            WalOp::Migrate { global, dest } => {
                b.push(2);
                b.extend_from_slice(&global.to_le_bytes());
                b.extend_from_slice(&dest.to_le_bytes());
            }
            WalOp::PinThreshold { ms } => {
                b.push(3);
                b.extend_from_slice(&ms.to_le_bytes());
            }
            WalOp::Split { cluster, new_cluster } => {
                b.push(4);
                b.extend_from_slice(&cluster.to_le_bytes());
                b.extend_from_slice(&new_cluster.to_le_bytes());
            }
            WalOp::Merge { source, victim } => {
                b.push(5);
                b.extend_from_slice(&source.to_le_bytes());
                b.extend_from_slice(&victim.to_le_bytes());
            }
        }
        b
    }

    /// Decode a payload. Strict: unknown tags, short reads and trailing
    /// bytes are all errors (the frame checksum catches corruption; this
    /// catches format drift).
    pub fn decode(bytes: &[u8]) -> Result<WalOp> {
        let mut c = Cursor { b: bytes, off: 0 };
        let op = match c.u8()? {
            0 => {
                let id = c.u32()?;
                let text_len = c.u32()? as usize;
                let text = String::from_utf8(c.bytes(text_len)?.to_vec())
                    .context("wal insert text is not utf-8")?;
                let emb_len = c.u32()? as usize;
                anyhow::ensure!(
                    emb_len <= (bytes.len() - c.off) / 4,
                    "wal insert embedding length overruns the record"
                );
                let mut emb = Vec::with_capacity(emb_len);
                for _ in 0..emb_len {
                    emb.push(c.f32()?);
                }
                WalOp::Insert { id, text, emb }
            }
            1 => WalOp::Remove { id: c.u32()? },
            2 => WalOp::Migrate { global: c.u32()?, dest: c.u32()? },
            3 => WalOp::PinThreshold { ms: c.f64()? },
            4 => WalOp::Split { cluster: c.u32()?, new_cluster: c.u32()? },
            5 => WalOp::Merge { source: c.u32()?, victim: c.u32()? },
            t => bail!("unknown wal record tag {t}"),
        };
        if c.off != bytes.len() {
            bail!("wal record has {} trailing bytes", bytes.len() - c.off);
        }
        Ok(op)
    }
}

/// Runtime activity counters of a [`WriteAheadLog`] — the durability
/// visibility row the `stats`/`metrics` endpoints expose. Counts are
/// since open; `replayed_ops` is what the last recovery handed back;
/// `bytes_on_disk` is measured from the filesystem on demand.
#[derive(Debug, Clone, Copy, Default)]
pub struct WalActivity {
    /// Records appended since this log was opened.
    pub frames_appended: u64,
    /// Snapshot rotations completed since open (interval-triggered and
    /// checkpoints).
    pub rotations: u64,
    /// Current bytes on disk: live log + published snapshot.
    pub bytes_on_disk: u64,
    /// Ops recovered (snapshot + surviving log tail) at the last open.
    pub replayed_ops: u64,
    /// Cumulative wall time spent inside `append` since open (ns).
    pub append_ns: u64,
    /// Cumulative wall time spent rotating snapshots since open (ns).
    pub rotate_ns: u64,
}

/// Bounds-checked little-endian reader over a payload.
struct Cursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.off < n {
            bail!("wal record truncated (need {n} bytes at offset {})", self.off);
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

/// FNV-1a 64 over the record's seq (LE bytes) then its payload. Seq is
/// included so a frame spliced from another position in the log (or
/// another log) fails verification even with an intact payload.
fn checksum(seq: u64, payload: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &byte in seq.to_le_bytes().iter().chain(payload.iter()) {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Encode one frame (header + payload) for `op` at `seq`.
fn encode_frame(seq: u64, op: &WalOp) -> Vec<u8> {
    let payload = op.encode();
    let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&seq.to_le_bytes());
    frame.extend_from_slice(&checksum(seq, &payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Scan frames from `bytes`, stopping (without error) at the first torn
/// or corrupt frame: short header, oversized or overrunning length,
/// checksum mismatch, or undecodable payload. Returns the good records
/// and the byte length of the valid prefix.
fn scan_frames(bytes: &[u8]) -> (Vec<(u64, WalOp)>, usize) {
    let mut recs = Vec::new();
    let mut off = 0usize;
    while bytes.len() - off >= FRAME_HEADER {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        if len > MAX_PAYLOAD || bytes.len() - off - FRAME_HEADER < len {
            break;
        }
        let seq = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
        let hash = u64::from_le_bytes(bytes[off + 12..off + 20].try_into().unwrap());
        let payload = &bytes[off + FRAME_HEADER..off + FRAME_HEADER + len];
        if checksum(seq, payload) != hash {
            break;
        }
        let Ok(op) = WalOp::decode(payload) else {
            break;
        };
        recs.push((seq, op));
        off += FRAME_HEADER + len;
    }
    (recs, off)
}

/// Mutable log state behind the append mutex.
struct WalInner {
    /// Append handle on the log file (`O_APPEND`; unbuffered).
    file: File,
    /// Sequence number the next append will use.
    next_seq: u64,
    /// Records appended to the log since the last rotation (counts the
    /// live log tail recovered at open, so the interval measures actual
    /// log length, not process uptime).
    since_snapshot: usize,
}

/// The structural write-ahead log: one per index, rooted in its own
/// directory (sibling of the blob dirs; derived per `(dataset, kind)` by
/// the builder so logs and datasets can never cross). See the module
/// docs for the record format, rotation protocol and crash-safety
/// argument.
///
/// Thread-safe: appends and rotations serialize on an internal mutex.
/// In the index lock hierarchy the append sits *inside* the structural
/// updates mutex (level 2) — the serialized structural ops give the log
/// its total order — and takes no index locks itself.
pub struct WriteAheadLog {
    dir: PathBuf,
    inner: Mutex<WalInner>,
    /// Rotate after this many log records (0 = never rotate; explicit
    /// [`WriteAheadLog::checkpoint`] still works).
    snapshot_interval: usize,
    /// Ops recovered at open (snapshot records then surviving log tail,
    /// in sequence order), drained once by
    /// [`WriteAheadLog::take_recovered`].
    recovered: Mutex<Vec<WalOp>>,
    /// Fault injection (crash-consistency tests): fail the next N
    /// appends *before* any bytes are written — the op aborts with
    /// neither a record nor a mutation.
    fail_append: AtomicU32,
    /// Fault injection: fail the next N appends *after* the record is
    /// durably written — simulates a crash between the WAL append and
    /// the in-memory mutation (the caller must abort pre-mutation;
    /// replay applies the surviving record).
    fail_post_append: AtomicU32,
    /// Fault injection: fail the next N rotations after the temp
    /// snapshot is written but before the atomic rename — a crash
    /// mid-snapshot.
    fail_rotate: AtomicU32,
    /// Fault injection: fail the next N rotations after the rename but
    /// before the log truncation — a crash between snapshot
    /// publication and log cleanup.
    fail_truncate: AtomicU32,
    /// Records appended since open (activity counter, not a seq).
    frames_appended: AtomicU64,
    /// Rotations completed since open.
    rotations: AtomicU64,
    /// Cumulative `append` wall time (ns).
    append_ns: AtomicU64,
    /// Cumulative rotation wall time (ns).
    rotate_ns: AtomicU64,
    /// Ops recovered at open (fixed after construction).
    replayed_ops: u64,
}

impl std::fmt::Debug for WriteAheadLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WriteAheadLog")
            .field("dir", &self.dir)
            .field("snapshot_interval", &self.snapshot_interval)
            .finish_non_exhaustive()
    }
}

impl WriteAheadLog {
    /// Open (creating if needed) the WAL rooted at `dir`, recovering its
    /// contents:
    ///
    /// 1. a stale temp snapshot (crash mid-rotation) is deleted;
    /// 2. the snapshot, if present, is read strictly (it was published
    ///    by an atomic rename, so corruption there is a real I/O fault,
    ///    not a torn write — it errors rather than silently dropping
    ///    ops);
    /// 3. the log is scanned tolerantly: records covered by the
    ///    snapshot's watermark are skipped (an interrupted truncation),
    ///    a torn or corrupt tail is cut back to the last good record,
    ///    and an interrupted truncation with no surviving tail is
    ///    completed.
    ///
    /// The recovered ops wait in [`WriteAheadLog::take_recovered`];
    /// appends continue from the next sequence number.
    pub fn open(dir: &Path, snapshot_interval: usize) -> Result<WriteAheadLog> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating wal dir {}", dir.display()))?;
        let tmp = dir.join(SNAPSHOT_TMP);
        if tmp.exists() {
            fs::remove_file(&tmp).context("removing stale wal snapshot temp")?;
        }

        // Snapshot: strict decode.
        let snap_path = dir.join(SNAPSHOT_FILE);
        let mut covered = 0u64;
        let mut ops: Vec<WalOp> = Vec::new();
        if snap_path.exists() {
            let bytes = fs::read(&snap_path)
                .with_context(|| format!("reading wal snapshot {}", snap_path.display()))?;
            let (c, recs) = decode_snapshot(&bytes)
                .with_context(|| format!("corrupt wal snapshot {}", snap_path.display()))?;
            covered = c;
            ops.extend(recs.into_iter().map(|(_, op)| op));
        }

        // Log: tolerant scan + tail truncation.
        let log_path = dir.join(LOG_FILE);
        let mut next_seq = covered + 1;
        let mut tail_records = 0usize;
        if log_path.exists() {
            let bytes = fs::read(&log_path)
                .with_context(|| format!("reading wal log {}", log_path.display()))?;
            let (recs, mut good_len) = scan_frames(&bytes);
            let mut pos = 0usize; // byte length of the seq-valid prefix
            for (seq, op) in recs {
                if seq <= covered {
                    // Interrupted truncation: already in the snapshot.
                    pos += FRAME_HEADER + op.encode().len();
                    continue;
                }
                if seq != next_seq {
                    // Sequence gap: treat everything from here as torn.
                    break;
                }
                pos += FRAME_HEADER + op.encode().len();
                next_seq = seq + 1;
                tail_records += 1;
                ops.push(op);
            }
            good_len = good_len.min(pos);
            let target = if tail_records == 0 { 0 } else { good_len };
            if (target as u64) < fs::metadata(&log_path)?.len() {
                let f = OpenOptions::new()
                    .write(true)
                    .open(&log_path)
                    .context("opening wal log for tail truncation")?;
                f.set_len(target as u64).context("truncating torn wal tail")?;
                f.sync_data().context("syncing truncated wal log")?;
            }
        }

        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&log_path)
            .with_context(|| format!("opening wal log {}", log_path.display()))?;

        let replayed_ops = ops.len() as u64;
        Ok(WriteAheadLog {
            dir: dir.to_path_buf(),
            inner: Mutex::new(WalInner {
                file,
                next_seq,
                since_snapshot: tail_records,
            }),
            snapshot_interval,
            recovered: Mutex::new(ops),
            fail_append: AtomicU32::new(0),
            fail_post_append: AtomicU32::new(0),
            fail_rotate: AtomicU32::new(0),
            fail_truncate: AtomicU32::new(0),
            frames_appended: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
            append_ns: AtomicU64::new(0),
            rotate_ns: AtomicU64::new(0),
            replayed_ops,
        })
    }

    /// Drain the ops recovered at open (snapshot then log tail, in
    /// sequence order). The builder replays these through the index's
    /// normal update paths *before* attaching the WAL, so replayed ops
    /// are not re-logged.
    pub fn take_recovered(&self) -> Vec<WalOp> {
        std::mem::take(&mut self.recovered.lock().unwrap())
    }

    /// Path of the live log file (crash-consistency tests tear this).
    pub fn log_path(&self) -> PathBuf {
        self.dir.join(LOG_FILE)
    }

    /// Path of the published snapshot file.
    pub fn snapshot_path(&self) -> PathBuf {
        self.dir.join(SNAPSHOT_FILE)
    }

    /// Path of the snapshot staging temp file.
    pub fn snapshot_tmp_path(&self) -> PathBuf {
        self.dir.join(SNAPSHOT_TMP)
    }

    /// Sequence number of the most recently appended record (0 when the
    /// log has never held one).
    pub fn last_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq - 1
    }

    /// Arm fault injection: the next `n` appends fail before writing.
    pub fn inject_append_failures(&self, n: u32) {
        self.fail_append.store(n, Ordering::SeqCst);
    }

    /// Arm fault injection: the next `n` appends fail *after* the record
    /// is durably written (crash between append and mutation).
    pub fn inject_post_append_failures(&self, n: u32) {
        self.fail_post_append.store(n, Ordering::SeqCst);
    }

    /// Arm fault injection: the next `n` rotations fail after staging
    /// the temp snapshot, before the rename (crash mid-snapshot).
    pub fn inject_rotate_failures(&self, n: u32) {
        self.fail_rotate.store(n, Ordering::SeqCst);
    }

    /// Arm fault injection: the next `n` rotations fail after the
    /// rename, before the log truncation.
    pub fn inject_truncate_failures(&self, n: u32) {
        self.fail_truncate.store(n, Ordering::SeqCst);
    }

    /// Consume one charge from an armed fault counter.
    fn take_fault(counter: &AtomicU32) -> bool {
        counter
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok()
    }

    /// Append one record, rotating afterwards if the interval elapsed.
    /// Must be called *before* the op's irreversible in-memory mutation;
    /// on error the caller aborts the op (the record may or may not be
    /// on disk — replay applies whatever survived, see the module docs).
    pub fn append(&self, op: &WalOp) -> Result<()> {
        if Self::take_fault(&self.fail_append) {
            bail!("injected wal fault: append (before write)");
        }
        // Structural ops are rare and disk-bound, so the two timestamps
        // are measured unconditionally: activity counters stay accurate
        // whether or not tracing is on.
        let started = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        let frame = encode_frame(seq, op);
        inner
            .file
            .write_all(&frame)
            .with_context(|| format!("appending wal record {seq}"))?;
        inner.next_seq = seq + 1;
        inner.since_snapshot += 1;
        self.frames_appended.fetch_add(1, Ordering::Relaxed);
        let elapsed = started.elapsed().as_nanos() as u64;
        self.append_ns.fetch_add(elapsed, Ordering::Relaxed);
        trace::record_since("wal.append", started, &[]);
        if Self::take_fault(&self.fail_post_append) {
            bail!("injected wal fault: crash after durable append of record {seq}");
        }
        if self.snapshot_interval > 0 && inner.since_snapshot >= self.snapshot_interval {
            self.rotate_locked(&mut inner)?;
        }
        Ok(())
    }

    /// Force a rotation now (clean-shutdown flush): consolidates
    /// snapshot + log into a fresh snapshot, fsyncs, truncates the log.
    /// After a checkpoint, recovery reads the snapshot alone.
    pub fn checkpoint(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.since_snapshot == 0 {
            return inner.file.sync_data().context("syncing wal log");
        }
        self.rotate_locked(&mut inner)
    }

    /// Snapshot rotation under the append mutex:
    ///
    /// ```text
    ///   [sync]     fsync the log (records being archived must be real)
    ///   [stage]    snapshot records + live log records → temp file,
    ///              fsynced                                   (fallible)
    ///   [publish]  atomic rename temp → snapshot; fsync the directory
    ///   [truncate] log → empty, fsynced
    /// ```
    ///
    /// A crash before [publish] leaves the old snapshot + full log; one
    /// between [publish] and [truncate] leaves the new snapshot + a log
    /// it fully covers (skipped by `seq` at recovery). Either way every
    /// record is readable from exactly one place or harmlessly two.
    fn rotate_locked(&self, inner: &mut WalInner) -> Result<()> {
        let started = Instant::now();
        inner.file.sync_data().context("syncing wal log before rotation")?;

        // Consolidate: archived records, then the live log's new tail.
        let snap_path = self.dir.join(SNAPSHOT_FILE);
        let mut covered = 0u64;
        let mut records: Vec<(u64, WalOp)> = Vec::new();
        if snap_path.exists() {
            let bytes = fs::read(&snap_path).context("reading wal snapshot for rotation")?;
            let (c, recs) = decode_snapshot(&bytes).context("corrupt wal snapshot at rotation")?;
            covered = c;
            records = recs;
        }
        let log_bytes = fs::read(self.log_path()).context("reading wal log for rotation")?;
        let (log_recs, good_len) = scan_frames(&log_bytes);
        // The in-process log can't have a torn tail — we wrote it.
        debug_assert_eq!(good_len, log_bytes.len());
        records.extend(log_recs.into_iter().filter(|&(seq, _)| seq > covered));
        let new_covered = records.last().map_or(covered, |&(seq, _)| seq);

        // Stage + fsync the temp snapshot.
        let mut buf = Vec::new();
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        buf.extend_from_slice(&new_covered.to_le_bytes());
        for (seq, op) in &records {
            buf.extend_from_slice(&encode_frame(*seq, op));
        }
        let tmp = self.dir.join(SNAPSHOT_TMP);
        fs::write(&tmp, &buf).context("staging wal snapshot")?;
        File::open(&tmp)
            .and_then(|f| f.sync_data())
            .context("syncing staged wal snapshot")?;
        if Self::take_fault(&self.fail_rotate) {
            bail!("injected wal fault: crash mid-snapshot (temp staged, not renamed)");
        }

        // Publish atomically, then make the rename itself durable.
        fs::rename(&tmp, &snap_path).context("publishing wal snapshot")?;
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        if Self::take_fault(&self.fail_truncate) {
            bail!("injected wal fault: crash between snapshot publication and log truncation");
        }

        // Truncate the now fully archived log.
        inner.file.set_len(0).context("truncating wal log after rotation")?;
        inner.file.sync_data().context("syncing truncated wal log")?;
        inner.since_snapshot = 0;
        self.rotations.fetch_add(1, Ordering::Relaxed);
        self.rotate_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        trace::record_since("wal.rotate", started, &[]);
        Ok(())
    }

    /// Activity counters since open plus current on-disk footprint.
    ///
    /// `bytes_on_disk` reads file metadata on demand (stats-path only,
    /// never on the append path); missing files count as zero.
    pub fn activity(&self) -> WalActivity {
        let file_len = |p: PathBuf| fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        WalActivity {
            frames_appended: self.frames_appended.load(Ordering::Relaxed),
            rotations: self.rotations.load(Ordering::Relaxed),
            bytes_on_disk: file_len(self.log_path()) + file_len(self.snapshot_path()),
            replayed_ops: self.replayed_ops,
            append_ns: self.append_ns.load(Ordering::Relaxed),
            rotate_ns: self.rotate_ns.load(Ordering::Relaxed),
        }
    }
}

/// Strict snapshot decode: magic + watermark header, then frames that
/// must consume the whole file with strictly ascending seqs ≤ watermark.
fn decode_snapshot(bytes: &[u8]) -> Result<(u64, Vec<(u64, WalOp)>)> {
    if bytes.len() < SNAPSHOT_MAGIC.len() + 8 {
        bail!("snapshot shorter than its header");
    }
    if &bytes[..SNAPSHOT_MAGIC.len()] != SNAPSHOT_MAGIC {
        bail!("bad snapshot magic");
    }
    let covered = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let body = &bytes[16..];
    let (recs, good_len) = scan_frames(body);
    if good_len != body.len() {
        bail!("snapshot body has {} undecodable trailing bytes", body.len() - good_len);
    }
    let mut prev = 0u64;
    for &(seq, _) in &recs {
        if seq <= prev || seq > covered {
            bail!("snapshot record seq {seq} out of order or past watermark {covered}");
        }
        prev = seq;
    }
    Ok((covered, recs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::testutil::test_seed;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("edgerag-wal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    /// Random op with text/embedding payloads of random shape.
    fn arb_op(rng: &mut Rng) -> WalOp {
        match rng.below(6) {
            0 => {
                let id = rng.below(10_000) as u32;
                let text: String = (0..rng.below(40))
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect();
                let emb: Vec<f32> = (0..rng.below(16)).map(|_| rng.f64() as f32).collect();
                WalOp::Insert { id, text, emb }
            }
            1 => WalOp::Remove { id: rng.below(10_000) as u32 },
            2 => WalOp::Migrate {
                global: rng.below(4_096) as u32,
                dest: rng.below(8) as u32,
            },
            3 => WalOp::PinThreshold { ms: rng.f64() * 100.0 },
            4 => WalOp::Split {
                cluster: rng.below(4_096) as u32,
                new_cluster: rng.below(4_096) as u32,
            },
            _ => WalOp::Merge {
                source: rng.below(4_096) as u32,
                victim: rng.below(4_096) as u32,
            },
        }
    }

    #[test]
    fn encode_decode_roundtrip_arbitrary_ops() {
        let mut rng = Rng::new(test_seed(0xEDE0));
        for _ in 0..500 {
            let op = arb_op(&mut rng);
            let bytes = op.encode();
            let back = WalOp::decode(&bytes).unwrap();
            assert_eq!(op, back, "roundtrip mismatch");
        }
    }

    #[test]
    fn decode_rejects_truncated_and_padded_payloads() {
        let mut rng = Rng::new(test_seed(0xEDE1));
        for _ in 0..200 {
            let op = arb_op(&mut rng);
            let bytes = op.encode();
            // Every strict prefix must fail (an Insert prefix could in
            // principle re-parse only if the length fields lie, which
            // they never do for a genuine encoding).
            let cut = rng.below(bytes.len());
            assert!(
                WalOp::decode(&bytes[..cut]).is_err(),
                "truncated payload decoded: {op:?} cut at {cut}"
            );
            let mut padded = bytes.clone();
            padded.push(0);
            assert!(WalOp::decode(&padded).is_err(), "trailing byte accepted");
        }
    }

    #[test]
    fn append_reopen_recovers_in_order() {
        let dir = tmpdir("reopen");
        let mut rng = Rng::new(test_seed(0xEDE2));
        let ops: Vec<WalOp> = (0..64).map(|_| arb_op(&mut rng)).collect();
        {
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            assert!(wal.take_recovered().is_empty());
            for op in &ops {
                wal.append(op).unwrap();
            }
            assert_eq!(wal.last_seq(), 64);
        }
        // Two independent reopens see the identical sequence (replay
        // determinism at the log layer).
        for _ in 0..2 {
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            assert_eq!(wal.take_recovered(), ops);
            assert_eq!(wal.last_seq(), 64);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_loses_only_the_last_record() {
        let mut rng = Rng::new(test_seed(0xEDE3));
        for round in 0..8 {
            let dir = tmpdir(&format!("torn-{round}"));
            let ops: Vec<WalOp> = (0..16).map(|_| arb_op(&mut rng)).collect();
            let log = {
                let wal = WriteAheadLog::open(&dir, 0).unwrap();
                for op in &ops {
                    wal.append(op).unwrap();
                }
                wal.log_path()
            };
            // Tear 1..=19 bytes off the end: always strictly inside the
            // final frame (its header alone is 20 bytes).
            let len = fs::metadata(&log).unwrap().len();
            let cut = 1 + rng.below(FRAME_HEADER - 1) as u64;
            OpenOptions::new()
                .write(true)
                .open(&log)
                .unwrap()
                .set_len(len - cut)
                .unwrap();
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            assert_eq!(wal.take_recovered(), ops[..15].to_vec(), "round {round}");
            // The torn bytes are gone and appends continue at seq 16.
            assert_eq!(wal.last_seq(), 15);
            wal.append(&ops[15]).unwrap();
            drop(wal);
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            assert_eq!(wal.take_recovered(), ops);
            fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn corrupt_byte_stops_recovery_at_last_good_record() {
        let mut rng = Rng::new(test_seed(0xEDE4));
        let dir = tmpdir("corrupt");
        let ops: Vec<WalOp> = (0..16).map(|_| arb_op(&mut rng)).collect();
        let log = {
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            for op in &ops {
                wal.append(op).unwrap();
            }
            wal.log_path()
        };
        // Flip the final byte (payload tail of the last record, or its
        // checksum for a zero-length payload — either fails the hash).
        let mut bytes = fs::read(&log).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&log, &bytes).unwrap();
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        let recovered = wal.take_recovered();
        assert_eq!(recovered, ops[..15].to_vec(), "checksum must reject the flipped record");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotation_consolidates_and_recovery_merges_snapshot_and_tail() {
        let mut rng = Rng::new(test_seed(0xEDE5));
        let dir = tmpdir("rotate");
        let ops: Vec<WalOp> = (0..22).map(|_| arb_op(&mut rng)).collect();
        {
            let wal = WriteAheadLog::open(&dir, 8).unwrap();
            for op in &ops {
                wal.append(op).unwrap();
            }
            // 22 appends at interval 8 → rotations at 8 and 16; the log
            // holds the 6-record tail, the snapshot the first 16.
            assert!(wal.snapshot_path().exists());
            assert!(!wal.snapshot_tmp_path().exists());
        }
        let wal = WriteAheadLog::open(&dir, 8).unwrap();
        assert_eq!(wal.take_recovered(), ops);
        assert_eq!(wal.last_seq(), 22);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_truncates_log_and_preserves_everything() {
        let mut rng = Rng::new(test_seed(0xEDE6));
        let dir = tmpdir("checkpoint");
        let ops: Vec<WalOp> = (0..10).map(|_| arb_op(&mut rng)).collect();
        {
            let wal = WriteAheadLog::open(&dir, 0).unwrap();
            for op in &ops {
                wal.append(op).unwrap();
            }
            wal.checkpoint().unwrap();
            assert_eq!(fs::metadata(wal.log_path()).unwrap().len(), 0);
            // Idempotent when nothing new arrived.
            wal.checkpoint().unwrap();
        }
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        assert_eq!(wal.take_recovered(), ops);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_mid_snapshot_keeps_old_snapshot_and_full_log() {
        let mut rng = Rng::new(test_seed(0xEDE7));
        let dir = tmpdir("midsnap");
        let ops: Vec<WalOp> = (0..4).map(|_| arb_op(&mut rng)).collect();
        {
            let wal = WriteAheadLog::open(&dir, 4).unwrap();
            wal.inject_rotate_failures(1);
            for op in &ops[..3] {
                wal.append(op).unwrap();
            }
            // The 4th append triggers rotation, which dies mid-stage.
            let err = wal.append(&ops[3]).unwrap_err();
            assert!(err.to_string().contains("mid-snapshot"), "{err}");
            assert!(wal.snapshot_tmp_path().exists());
            assert!(!wal.snapshot_path().exists());
        }
        // Recovery discards the temp and replays the intact log —
        // including the record whose rotation died.
        let wal = WriteAheadLog::open(&dir, 4).unwrap();
        assert!(!wal.snapshot_tmp_path().exists());
        assert_eq!(wal.take_recovered(), ops);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_before_truncation_never_double_applies() {
        let mut rng = Rng::new(test_seed(0xEDE8));
        let dir = tmpdir("trunc");
        let ops: Vec<WalOp> = (0..4).map(|_| arb_op(&mut rng)).collect();
        {
            let wal = WriteAheadLog::open(&dir, 4).unwrap();
            wal.inject_truncate_failures(1);
            for op in &ops[..3] {
                wal.append(op).unwrap();
            }
            let err = wal.append(&ops[3]).unwrap_err();
            assert!(err.to_string().contains("truncation"), "{err}");
            // Snapshot published, log NOT truncated: every record now
            // exists in both places.
            assert!(wal.snapshot_path().exists());
            assert!(fs::metadata(wal.log_path()).unwrap().len() > 0);
        }
        // Recovery skips the covered log records (no duplicates) and
        // completes the interrupted truncation.
        let wal = WriteAheadLog::open(&dir, 4).unwrap();
        assert_eq!(wal.take_recovered(), ops);
        assert_eq!(fs::metadata(wal.log_path()).unwrap().len(), 0);
        assert_eq!(wal.last_seq(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pre_write_fault_leaves_no_record() {
        let dir = tmpdir("prefault");
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        wal.append(&WalOp::Remove { id: 1 }).unwrap();
        wal.inject_append_failures(1);
        assert!(wal.append(&WalOp::Remove { id: 2 }).is_err());
        wal.append(&WalOp::Remove { id: 3 }).unwrap();
        drop(wal);
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        assert_eq!(
            wal.take_recovered(),
            vec![WalOp::Remove { id: 1 }, WalOp::Remove { id: 3 }]
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn post_write_fault_preserves_the_record() {
        let dir = tmpdir("postfault");
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        wal.inject_post_append_failures(1);
        assert!(wal.append(&WalOp::Remove { id: 7 }).is_err());
        drop(wal);
        // The record was durably written before the simulated crash, so
        // replay sees it.
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        assert_eq!(wal.take_recovered(), vec![WalOp::Remove { id: 7 }]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spliced_record_fails_checksum() {
        // A frame copied to a different seq position must be rejected
        // even though its payload bytes are intact (seq is hashed).
        let dir = tmpdir("splice");
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        wal.append(&WalOp::Remove { id: 1 }).unwrap();
        wal.append(&WalOp::Remove { id: 2 }).unwrap();
        let log = wal.log_path();
        drop(wal);
        let bytes = fs::read(&log).unwrap();
        let first_len = FRAME_HEADER + WalOp::Remove { id: 1 }.encode().len();
        // Duplicate frame 1 after frame 2: seq 1 ≠ expected 3.
        let mut spliced = bytes.clone();
        spliced.extend_from_slice(&bytes[..first_len]);
        fs::write(&log, &spliced).unwrap();
        let wal = WriteAheadLog::open(&dir, 0).unwrap();
        assert_eq!(
            wal.take_recovered(),
            vec![WalOp::Remove { id: 1 }, WalOp::Remove { id: 2 }]
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn activity_counts_appends_rotations_and_replay() {
        let dir = tmpdir("activity");
        let wal = WriteAheadLog::open(&dir, 3).unwrap();
        for id in 0..5 {
            wal.append(&WalOp::Remove { id }).unwrap();
        }
        let a = wal.activity();
        assert_eq!(a.frames_appended, 5);
        assert_eq!(a.rotations, 1, "interval 3 fires once in 5 appends");
        assert_eq!(a.replayed_ops, 0, "fresh dir recovered nothing");
        assert!(a.bytes_on_disk > 0, "snapshot + log tail should have bytes");
        assert!(a.append_ns > 0);
        assert!(a.rotate_ns > 0);
        drop(wal);

        // Reopen: counters reset, replayed_ops reports the recovery.
        let wal = WriteAheadLog::open(&dir, 3).unwrap();
        let a = wal.activity();
        assert_eq!(a.frames_appended, 0);
        assert_eq!(a.rotations, 0);
        assert_eq!(a.replayed_ops, 5);
        wal.checkpoint().unwrap();
        assert_eq!(wal.activity().rotations, 1, "checkpoint counts as rotation");
        fs::remove_dir_all(&dir).unwrap();
    }
}
