//! Memory budget + thrash model.
//!
//! The paper's central observation (§3.1, Fig. 3): when the embedding
//! database exceeds device memory, both Flat and IVF baselines thrash —
//! every access to a paged-out region pays storage-rate page-ins, and the
//! generation model itself gets evicted, inflating first-token latency.
//!
//! This model tracks resident regions under a fixed capacity with LRU
//! eviction at page granularity. Callers convert faulted bytes into
//! modeled latency through the [`StorageDevice`](super::StorageDevice).

use std::collections::HashMap;

/// A unit of residency accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Region {
    /// One page of the generation model's weights.
    LlmPage(u32),
    /// The level-1 centroid table (small; effectively always hot).
    Centroids,
    /// One cluster's second-level embeddings (IVF baseline residency).
    Cluster(u32),
    /// One cached generated-embedding entry (EdgeRAG cache accounting).
    Cache(u32),
    /// One page of the flat index's embedding array.
    FlatPage(u32),
}

#[derive(Debug)]
struct Entry {
    bytes: u64,
    last_use: u64,
}

/// LRU-evicting residency model under a byte capacity.
#[derive(Debug)]
pub struct MemoryModel {
    capacity: u64,
    used: u64,
    clock: u64,
    resident: HashMap<Region, Entry>,
    faults: u64,
    fault_bytes: u64,
    evictions: u64,
}

/// Page size for LLM-weight and flat-index residency accounting.
pub const PAGE_BYTES: u64 = 1 << 20;

impl MemoryModel {
    pub fn new(capacity: u64) -> Self {
        MemoryModel {
            capacity,
            used: 0,
            clock: 0,
            resident: HashMap::new(),
            faults: 0,
            fault_bytes: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn is_resident(&self, r: Region) -> bool {
        self.resident.contains_key(&r)
    }

    pub fn faults(&self) -> u64 {
        self.faults
    }

    pub fn fault_bytes(&self) -> u64 {
        self.fault_bytes
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Access `r` (sized `bytes`). Returns the number of bytes that had to
    /// be faulted in (0 on a residency hit). Evicts LRU entries as needed;
    /// an access larger than capacity still faults its full size but only
    /// the tail that fits stays resident.
    pub fn touch(&mut self, r: Region, bytes: u64) -> u64 {
        self.clock += 1;
        if let Some(e) = self.resident.get_mut(&r) {
            e.last_use = self.clock;
            return 0;
        }
        self.faults += 1;
        self.fault_bytes += bytes;
        let keep = bytes.min(self.capacity);
        self.make_room(keep, Some(r));
        self.used += keep;
        self.resident.insert(
            r,
            Entry {
                bytes: keep,
                last_use: self.clock,
            },
        );
        bytes
    }

    /// Access that never faults storage (freshly generated data being
    /// installed, e.g. cache inserts). Still consumes capacity and may
    /// evict others. Returns bytes evicted to make room.
    pub fn install(&mut self, r: Region, bytes: u64) -> u64 {
        self.clock += 1;
        if let Some(e) = self.resident.get_mut(&r) {
            e.last_use = self.clock;
            return 0;
        }
        let keep = bytes.min(self.capacity);
        let evicted = self.make_room(keep, Some(r));
        self.used += keep;
        self.resident.insert(
            r,
            Entry {
                bytes: keep,
                last_use: self.clock,
            },
        );
        evicted
    }

    /// Explicitly drop a region (cache eviction, index removal).
    pub fn release(&mut self, r: Region) {
        if let Some(e) = self.resident.remove(&r) {
            self.used -= e.bytes;
        }
    }

    fn make_room(&mut self, bytes: u64, skip: Option<Region>) -> u64 {
        let mut evicted = 0;
        while self.used + bytes > self.capacity {
            let victim = self
                .resident
                .iter()
                .filter(|(r, _)| Some(**r) != skip)
                .min_by_key(|(_, e)| e.last_use)
                .map(|(r, _)| *r);
            match victim {
                Some(v) => {
                    let e = self.resident.remove(&v).unwrap();
                    self.used -= e.bytes;
                    evicted += e.bytes;
                    self.evictions += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Touch all pages of a paged range (LLM weights, flat index), returning
    /// total faulted bytes. `base` distinguishes ranges.
    pub fn touch_paged<F: Fn(u32) -> Region>(&mut self, make: F, total: u64) -> u64 {
        let mut faulted = 0;
        let pages = total.div_ceil(PAGE_BYTES);
        for p in 0..pages {
            let sz = PAGE_BYTES.min(total - p * PAGE_BYTES);
            faulted += if self.touch(make(p as u32), sz) > 0 { sz } else { 0 };
        }
        faulted
    }

    pub fn reset_stats(&mut self) {
        self.faults = 0;
        self.fault_bytes = 0;
        self.evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_touch() {
        let mut m = MemoryModel::new(10 * PAGE_BYTES);
        assert_eq!(m.touch(Region::Cluster(1), PAGE_BYTES), PAGE_BYTES);
        assert_eq!(m.touch(Region::Cluster(1), PAGE_BYTES), 0);
        assert_eq!(m.faults(), 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut m = MemoryModel::new(2 * PAGE_BYTES);
        m.touch(Region::Cluster(1), PAGE_BYTES);
        m.touch(Region::Cluster(2), PAGE_BYTES);
        m.touch(Region::Cluster(1), PAGE_BYTES); // refresh 1
        m.touch(Region::Cluster(3), PAGE_BYTES); // evicts 2 (LRU)
        assert!(m.is_resident(Region::Cluster(1)));
        assert!(!m.is_resident(Region::Cluster(2)));
        assert!(m.is_resident(Region::Cluster(3)));
    }

    #[test]
    fn thrash_when_working_set_exceeds_capacity() {
        // The Fig. 3 phenomenon: a cycle over capacity+1 regions faults on
        // every single access.
        let mut m = MemoryModel::new(3 * PAGE_BYTES);
        let mut faults = 0;
        for round in 0..4 {
            for c in 0..4u32 {
                if m.touch(Region::Cluster(c), PAGE_BYTES) > 0 && round > 0 {
                    faults += 1;
                }
            }
        }
        assert_eq!(faults, 12, "every post-warmup access must fault");
    }

    #[test]
    fn working_set_within_capacity_never_refaults() {
        let mut m = MemoryModel::new(4 * PAGE_BYTES);
        for _ in 0..3 {
            for c in 0..4u32 {
                m.touch(Region::Cluster(c), PAGE_BYTES);
            }
        }
        assert_eq!(m.faults(), 4); // only cold misses
    }

    #[test]
    fn release_frees_capacity() {
        let mut m = MemoryModel::new(PAGE_BYTES);
        m.touch(Region::Cache(1), PAGE_BYTES);
        assert_eq!(m.used_bytes(), PAGE_BYTES);
        m.release(Region::Cache(1));
        assert_eq!(m.used_bytes(), 0);
        assert_eq!(m.touch(Region::Cache(2), PAGE_BYTES), PAGE_BYTES);
        assert_eq!(m.evictions(), 0, "no eviction needed after release");
    }

    #[test]
    fn oversized_touch_keeps_capacity_invariant() {
        let mut m = MemoryModel::new(2 * PAGE_BYTES);
        let faulted = m.touch(Region::Cluster(9), 5 * PAGE_BYTES);
        assert_eq!(faulted, 5 * PAGE_BYTES);
        assert!(m.used_bytes() <= m.capacity());
    }

    #[test]
    fn paged_touch_faults_only_missing_pages() {
        let mut m = MemoryModel::new(64 * PAGE_BYTES);
        let total = 10 * PAGE_BYTES + 1234;
        let f1 = m.touch_paged(Region::LlmPage, total);
        assert_eq!(f1, total);
        let f2 = m.touch_paged(Region::LlmPage, total);
        assert_eq!(f2, 0);
        // evict one page; only that page refaults
        m.release(Region::LlmPage(3));
        let f3 = m.touch_paged(Region::LlmPage, total);
        assert_eq!(f3, PAGE_BYTES);
    }

    #[test]
    fn llm_evicted_by_cluster_pressure() {
        // LLM resident; streaming clusters through a tight budget evicts it.
        let mut m = MemoryModel::new(8 * PAGE_BYTES);
        m.touch_paged(Region::LlmPage, 6 * PAGE_BYTES);
        for c in 0..8u32 {
            m.touch(Region::Cluster(c), PAGE_BYTES);
        }
        let refault = m.touch_paged(Region::LlmPage, 6 * PAGE_BYTES);
        assert!(refault > 0, "model must have been partially evicted");
    }
}
