//! `edgerag` — the CLI launcher.
//!
//! Subcommands:
//!   serve    start the serving coordinator on a TCP port
//!   query    send one query to a running server
//!   bench    regenerate a paper table/figure (see DESIGN.md §5)
//!   build    pre-build dataset caches (embeddings + clustering)
//!   tune     nprobe tuning against the flat baseline (paper §6.2)
//!   config   print the default system config as JSON

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::embedding::EmbedderBackend;
use edgerag::eval::experiments::{self, ExperimentCtx, DEFAULT_QUERY_LIMIT};
use edgerag::json::Value;
use edgerag::runtime::ComputeHandle;
use edgerag::server::{Client, Server};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny argv parser: positional command + `--key value` / `--flag` pairs.
struct Args {
    command: String,
    positional: Vec<String>,
    named: HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut argv = std::env::args().skip(1);
        let command = argv.next().unwrap_or_else(|| "help".into());
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let is_flag = i + 1 >= rest.len() || rest[i + 1].starts_with("--");
                if is_flag {
                    named.insert(key.to_string(), "true".into());
                    i += 1;
                } else {
                    named.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                }
            } else {
                positional.push(rest[i].clone());
                i += 1;
            }
        }
        Args {
            command,
            positional,
            named,
        }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }
}

fn builder_from(args: &Args) -> Result<SystemBuilder> {
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    // 0 = auto (one executor per core, clamped to 16). Only the PJRT
    // backend has an executor pool; the reference backend runs inline.
    let compute_threads: usize = args
        .get("compute-threads")
        .map(|t| t.parse())
        .transpose()
        .context("bad --compute-threads")?
        .unwrap_or(0);
    let compute =
        ComputeHandle::start_with_threads(std::path::Path::new(artifacts), compute_threads)
            .context("starting compute executor (run `make artifacts` first)")?;
    let device = match args.get("device") {
        Some(name) => {
            DeviceProfile::by_name(name).with_context(|| format!("unknown device `{name}`"))?
        }
        None => DeviceProfile::jetson_orin_nano(),
    };
    let mut b = SystemBuilder::new(compute, device);
    if let Some(np) = args.get("nprobe") {
        b.retrieval.nprobe = np.parse().context("bad --nprobe")?;
    }
    if let Some(k) = args.get("top-k") {
        b.retrieval.top_k = k.parse().context("bad --top-k")?;
    }
    if args.flag("transformer") {
        b.options.backend = EmbedderBackend::Transformer;
    }
    if args.flag("live-generation") {
        b.options.prebuilt_generation = false;
    }
    if args.flag("real-prefill") {
        b.options.real_prefill = true;
    }
    Ok(b)
}

fn dataset_from(args: &Args) -> Result<DatasetProfile> {
    let name = args.get("dataset").unwrap_or("tiny");
    DatasetProfile::by_name(name).with_context(|| format!("unknown dataset `{name}`"))
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.command.as_str() {
        "serve" => serve(&args),
        "query" => query(&args),
        "stats" => stats(&args),
        "bench" => bench(&args),
        "bench-validate" => bench_validate(&args),
        "build" => build(&args),
        "tune" => tune(&args),
        "config" => config(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command `{other}`")
        }
    }
}

fn print_help() {
    println!(
        "edgerag — online-indexed RAG for edge devices (paper reproduction)

USAGE: edgerag <command> [--options]

COMMANDS
  serve   --dataset NAME --index KIND [--port P] [--device D]
          [--workers N] [--shards N] [--batching true|false]
          [--batch-window-us U] [--max-inflight N]
          [--rebalance true|false] [--rebalance-interval N]
          [--max-migrations N] [--heat-decay-interval N]
          [--shards-min N] [--shards-max N] [--compute-threads N]
          [--wal true|false] [--wal-dir PATH]
          [--snapshot-interval-ops N]
          [--trace true|false] [--slow-query-us U] [--deadline-us U]
          [--transformer] [--real-prefill] [--live-generation]
          (--compute-threads 0 = auto, one PJRT executor per core;
           ignored by the inline reference backend)
          (--shards 0 = auto, one per core — the serve default;
           --shards 1 = single-shard paper-exact index;
           --batching true — the serve default — coalesces concurrent
           queries' embed/probe kernel calls into fused batches;
           --rebalance true — the serve default — migrates hot clusters
           between shards online when placement drifts under updates;
           --heat-decay-interval N halves every probe-heat counter (and
           prunes the co-probe affinity table) every N update ops so
           placement tracks current traffic, not lifetime totals
           (0 = never decay); --shards-min/--shards-max bound the
           {{\"op\":\"reshard\",\"shards\":N}} elastic-topology op
           (--shards-max 0 = only the hard 256-shard limit);
           --wal true — the serve default — logs structural updates to a
           write-ahead log and replays it on restart; --wal-dir overrides
           the per-dataset default location; --snapshot-interval-ops 0
           compacts the log only on clean shutdown;
           --trace true — the serve default — captures per-query span
           trees into bounded rings, queryable via {{\"op\":\"trace\"}};
           queries slower than --slow-query-us land in the always-kept
           slow ring;
           --deadline-us 0 — the default — derives each query's deadline
           as 4 × slow-query-us; a query still queued when its deadline
           expires is shed with a \"deadline exceeded\" error instead of
           executed, and batch stages close early for expiring riders)
  query   --text \"...\" [--port P]
  stats   [--port P]
  bench   <table2|fig3|fig4|fig5|fig7|fig10|fig12|fig13|breakdown|
           headline|ablation-storage|ablation-decay|all>
          [--dataset NAME] [--full] [--limit N] [--device D]
  bench-validate [--file PATH]          check a BENCH_*.json against the schema
  build   [--dataset NAME|--all]        pre-build dataset caches
  tune    --dataset NAME                nprobe normalization vs flat
  config                                print default config JSON

INDEX KINDS: flat ivf ivf+gen ivf+gen+load edgerag
DATASETS:    tiny scidocs fiqa quora nq hotpotqa fever"
    );
}

fn serve(args: &Args) -> Result<()> {
    let mut builder = builder_from(args)?;
    let dataset = dataset_from(args)?;
    let kind = match args.get("index") {
        Some(k) => IndexKind::by_name(k).with_context(|| format!("unknown index `{k}`"))?,
        None => IndexKind::EdgeRag,
    };
    let port = args.get("port").unwrap_or("7313");
    let workers = match args.get("workers") {
        Some(w) => w.parse().context("bad --workers")?,
        None => edgerag::server::default_workers(),
    };
    // Serving defaults to the sharded index (one shard per core) so
    // probes fan out and inserts stall only their owning shard; the
    // library/config default stays 1 (paper-exact single shard).
    builder.retrieval.shards = match args.get("shards") {
        Some(s) => s.parse().context("bad --shards")?,
        None => 0, // auto
    };
    // Serving also defaults to cross-query batching (fused kernel calls
    // under concurrent load); the library/config default stays off.
    // `--batching false` disables; anything else but true/false errors
    // loudly rather than silently picking a mode.
    builder.retrieval.batching = match args.get("batching") {
        Some("true") | None => true,
        Some("false") => false,
        Some(other) => bail!("bad --batching `{other}` (expected true or false)"),
    };
    if let Some(w) = args.get("batch-window-us") {
        builder.retrieval.batch_window_us = w.parse().context("bad --batch-window-us")?;
    }
    if let Some(m) = args.get("max-inflight") {
        builder.retrieval.max_inflight = m.parse().context("bad --max-inflight")?;
    }
    // Serving defaults to online cross-shard rebalancing (the round-robin
    // placement drifts under online updates); the library/config default
    // stays off. Same strict true/false parse as --batching.
    builder.retrieval.rebalance = match args.get("rebalance") {
        Some("true") | None => true,
        Some("false") => false,
        Some(other) => bail!("bad --rebalance `{other}` (expected true or false)"),
    };
    if let Some(n) = args.get("rebalance-interval") {
        builder.retrieval.rebalance_interval_ops =
            n.parse().context("bad --rebalance-interval")?;
    }
    if let Some(n) = args.get("max-migrations") {
        builder.retrieval.max_migrations_per_round =
            n.parse().context("bad --max-migrations")?;
    }
    if let Some(n) = args.get("heat-decay-interval") {
        builder.retrieval.heat_decay_interval_ops =
            n.parse().context("bad --heat-decay-interval")?;
    }
    // Elastic-topology bounds for the `reshard` server op: an operator
    // can grow/shrink the live shard count online within [min, max].
    if let Some(n) = args.get("shards-min") {
        builder.retrieval.shards_min = n.parse().context("bad --shards-min")?;
    }
    if let Some(n) = args.get("shards-max") {
        builder.retrieval.shards_max = n.parse().context("bad --shards-max")?;
    }
    // Serving defaults to durability: structural updates go through the
    // write-ahead log and are replayed on restart. The library/config
    // default stays off (benchmarks and tests build throwaway indexes).
    // Same strict true/false parse as --batching.
    builder.retrieval.wal = match args.get("wal") {
        Some("true") | None => true,
        Some("false") => false,
        Some(other) => bail!("bad --wal `{other}` (expected true or false)"),
    };
    if let Some(dir) = args.get("wal-dir") {
        builder.options.wal_dir = Some(std::path::PathBuf::from(dir));
    }
    if let Some(n) = args.get("snapshot-interval-ops") {
        builder.retrieval.snapshot_interval_ops =
            n.parse().context("bad --snapshot-interval-ops")?;
    }
    // Serving defaults to the query-scoped tracing plane (per-stage span
    // attribution, slow-query capture, the `trace`/`metrics` ops); the
    // library/config default stays off — a library embedder never pays
    // even the one-atomic-load record sites' ring bookkeeping. Same
    // strict true/false parse as --batching.
    builder.retrieval.trace = match args.get("trace") {
        Some("true") | None => true,
        Some("false") => false,
        Some(other) => bail!("bad --trace `{other}` (expected true or false)"),
    };
    if let Some(us) = args.get("slow-query-us") {
        builder.retrieval.slow_query_us = us.parse().context("bad --slow-query-us")?;
    }
    // Per-query deadline budget: 0 (the default) derives it from the
    // slow-query threshold (4 × slow_query_us) so overloaded servers
    // shed stale queries instead of executing work nobody is waiting
    // for. An explicit huge value effectively disables shedding.
    if let Some(us) = args.get("deadline-us") {
        builder.retrieval.deadline_us = us.parse().context("bad --deadline-us")?;
    }
    let shards = builder.retrieval.resolved_shards();
    eprintln!("building dataset `{}` ({} chunks)…", dataset.name, dataset.n_chunks);
    let built = builder.build_dataset(&dataset)?;
    let pipeline = builder.pipeline(&built, kind)?;
    let addr = format!("127.0.0.1:{port}");
    let server = Server::bind_with_retrieval(
        &addr,
        pipeline,
        builder.embedder(),
        workers,
        &builder.retrieval,
    )?;
    eprintln!(
        "serving `{}` with {} index on {addr} (device: {}, {workers} workers, {shards} shard(s), \
         batching {}, rebalance {}, wal {}, trace {}, deadline {}µs)",
        dataset.name,
        kind.name(),
        builder.device.name,
        if builder.retrieval.batching { "on" } else { "off" },
        if builder.retrieval.rebalance { "on" } else { "off" },
        if builder.retrieval.wal { "on" } else { "off" },
        if builder.retrieval.trace { "on" } else { "off" },
        builder.retrieval.resolved_deadline_us()
    );
    server.run()
}

fn query(args: &Args) -> Result<()> {
    let port = args.get("port").unwrap_or("7313");
    let text = args.get("text").context("--text required")?;
    let mut client = Client::connect(&format!("127.0.0.1:{port}"))?;
    let resp = client.query(text)?;
    println!("{}", resp.pretty());
    Ok(())
}

fn stats(args: &Args) -> Result<()> {
    let port = args.get("port").unwrap_or("7313");
    let mut client = Client::connect(&format!("127.0.0.1:{port}"))?;
    let resp = client.call(&Value::object(vec![("op", Value::str("stats"))]))?;
    println!("{}", resp.pretty());
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("headline");
    let builder = builder_from(args)?;
    let query_limit = if args.flag("full") {
        None
    } else {
        Some(
            args.get("limit")
                .map(|l| l.parse())
                .transpose()
                .context("bad --limit")?
                .unwrap_or(DEFAULT_QUERY_LIMIT),
        )
    };
    let ctx = ExperimentCtx {
        builder,
        query_limit,
    };
    let ds = |default: &str| {
        args.get("dataset")
            .map(String::from)
            .unwrap_or_else(|| default.to_string())
    };
    match what {
        "table2" => experiments::table2(&ctx).map(drop),
        "fig3" => experiments::fig3(&ctx).map(drop),
        "fig4" => experiments::fig4(&ctx).map(drop),
        "fig5" => experiments::fig5(&ctx, &ds("nq")).map(drop),
        "fig7" => experiments::fig7(&ctx, &ds("fever")).map(drop),
        "fig10" | "fig11" => experiments::fig10_11(&ctx).map(drop),
        "fig12" => experiments::fig12(&ctx, &ds("nq")).map(drop),
        "fig13" => experiments::fig13(&ctx).map(drop),
        "breakdown" | "fig6" => experiments::breakdown(&ctx, &ds("nq")).map(drop),
        "headline" => experiments::headline(&ctx).map(drop),
        "ablation-storage" => experiments::ablation_storage(&ctx, &ds("fever")).map(drop),
        "ablation-decay" => experiments::ablation_decay(&ctx, &ds("fever")).map(drop),
        "all" => {
            experiments::table2(&ctx)?;
            experiments::fig3(&ctx)?;
            experiments::fig4(&ctx)?;
            experiments::fig5(&ctx, "nq")?;
            experiments::breakdown(&ctx, "nq")?;
            experiments::fig7(&ctx, "fever")?;
            experiments::fig10_11(&ctx)?;
            experiments::fig12(&ctx, "nq")?;
            experiments::fig13(&ctx)?;
            experiments::headline(&ctx)?;
            experiments::ablation_storage(&ctx, "fever")?;
            experiments::ablation_decay(&ctx, "fever")?;
            Ok(())
        }
        other => bail!("unknown bench `{other}` (see `edgerag help`)"),
    }
}

/// Validate a `BENCH_*.json` trajectory file against the
/// `edgerag-bench/v1` schema (see README "Benchmark trajectory"). Used
/// by the CI `bench-smoke` job after running both benches, and by hand
/// before committing an updated trajectory.
fn bench_validate(args: &Args) -> Result<()> {
    let path = args.get("file").unwrap_or("BENCH_10.json");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = edgerag::json::parse(&text).with_context(|| format!("parsing {path}"))?;

    let stat_keys = ["mean_ns", "p50_ns", "p95_ns"];
    let sweep_keys = ["qps", "p50_us", "p95_us", "p99_us"];

    let schema = v.req("schema")?.as_str().context("`schema` must be a string")?;
    anyhow::ensure!(
        schema == "edgerag-bench/v1",
        "unknown schema `{schema}` (expected edgerag-bench/v1)"
    );
    v.req("backend")?.as_str().context("`backend` must be a string")?;

    let micro = v.req("micro_hotpath")?;
    let kernels = micro
        .req("kernels")?
        .as_object()
        .context("`micro_hotpath.kernels` must be an object")?;
    anyhow::ensure!(!kernels.is_empty(), "`micro_hotpath.kernels` is empty");
    for (name, stats) in kernels {
        for key in stat_keys {
            stats
                .req(key)?
                .as_f64()
                .with_context(|| format!("kernel `{name}`: `{key}` must be a number"))?;
        }
    }
    for pair in ["dot", "sim", "proj"] {
        for leg in ["scalar", "simd"] {
            anyhow::ensure!(
                kernels.contains_key(&format!("{pair}_{leg}")),
                "missing A/B kernel entry `{pair}_{leg}`"
            );
        }
        micro
            .req("speedup")?
            .req(pair)?
            .as_f64()
            .with_context(|| format!("`speedup.{pair}` must be a number"))?;
    }

    let tput = v.req("throughput_scaling")?;
    for sweep in [
        "shard_sweep",
        "batching_sweep",
        "executor_pool",
        "tracing_sweep",
        "connection_sweep",
        "resharding_sweep",
    ] {
        let rows = tput
            .req(sweep)?
            .as_array()
            .with_context(|| format!("`throughput_scaling.{sweep}` must be an array"))?;
        anyhow::ensure!(!rows.is_empty(), "`throughput_scaling.{sweep}` is empty");
        for (i, row) in rows.iter().enumerate() {
            for key in sweep_keys {
                row.req(key)?
                    .as_f64()
                    .with_context(|| format!("{sweep}[{i}]: `{key}` must be a number"))?;
            }
        }
    }

    println!("{path}: valid edgerag-bench/v1 trajectory");
    Ok(())
}

fn build(args: &Args) -> Result<()> {
    let builder = builder_from(args)?;
    let datasets: Vec<DatasetProfile> = if args.flag("all") {
        DatasetProfile::beir_suite()
    } else {
        vec![dataset_from(args)?]
    };
    for p in datasets {
        let t = std::time::Instant::now();
        let built = builder.build_dataset(&p)?;
        println!(
            "built `{}`: {} chunks, {} clusters, {:.1}s",
            p.name,
            built.corpus.len(),
            built.centroids.len(),
            t.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn tune(args: &Args) -> Result<()> {
    let builder = builder_from(args)?;
    let dataset = dataset_from(args)?;
    let built = builder.build_dataset(&dataset)?;
    let sample = args
        .get("sample")
        .map(|s| s.parse())
        .transpose()
        .context("bad --sample")?
        .unwrap_or(100);
    let np = edgerag::eval::harness::tune_nprobe(&builder, &built, 0.05, sample)?;
    println!("dataset `{}`: nprobe = {np} normalizes recall to flat (±5%)", dataset.name);
    Ok(())
}

fn config(args: &Args) -> Result<()> {
    let dataset = dataset_from(args)?;
    let cfg = edgerag::config::SystemConfig::new(dataset, IndexKind::EdgeRag);
    println!("{}", cfg.to_json().pretty());
    Ok(())
}
