//! Configuration system: device profiles, dataset profiles, index/serving
//! configuration. Everything serializes to JSON (via the in-tree `json`
//! substrate) so deployments ship a config file; built-in profiles mirror
//! the paper's testbed (Table 1/3) and evaluated datasets (Table 2) at the
//! 1:100 scale DESIGN.md §3 documents.

use std::path::Path;

use anyhow::{Context, Result};

use crate::json::{self, Value};
use crate::simtime::SimDuration;

/// Physical characteristics of the modeled edge device.
///
/// Calibration (see DESIGN.md §3 and EXPERIMENTS.md): rates are chosen so
/// the paper's observed phenomena hold in our scaled world —
/// * embedding generation beats storage loads below ~24 kB of cluster text
///   (paper Fig. 4 crossover) because small scattered blobs pay SD-card
///   random-IO rates while generation is compute-rate-bound;
/// * large precomputed blobs are contiguous and load at sequential
///   bandwidth, which is why storing only the heavy tail wins (Fig. 12);
/// * datasets whose embedding DB exceeds the memory budget thrash, with
///   page-ins at random-IO rates plus LLM-weight eviction (Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Total memory available to the RAG process (embeddings + cache + LLM).
    pub mem_total_bytes: u64,
    /// Resident size of the generation model's weights.
    pub llm_weight_bytes: u64,
    /// Fixed overhead per online embedding-generation call (dispatch,
    /// tokenize, kernel launch).
    pub embed_gen_overhead_us: u64,
    /// Embedding-generation throughput of the device NPU/GPU, in corpus
    /// characters per second.
    pub embed_gen_chars_per_sec: f64,
    /// Seek / open latency for a contiguous blob read.
    pub storage_seek_us: u64,
    /// Fixed overhead of a *scattered* read (extent-map walk + queueing of
    /// the dozens of small random IOs a paged-out FAISS cluster needs).
    /// This constant, together with the two bandwidths, places the paper's
    /// Fig. 4 gen-vs-load crossover at ~24 kB of cluster text.
    pub storage_scatter_overhead_us: u64,
    /// Small scattered (page-sized) read bandwidth — SD UHS-I random IO.
    pub storage_random_bps: f64,
    /// Contiguous blob read bandwidth — SD UHS-I sequential.
    pub storage_seq_bps: f64,
    /// Effective bandwidth of *thrash* page-ins (4 KiB mmap fault storms
    /// with page-cache churn and write-back interference — far worse than
    /// a clean scattered read of the same bytes; this is what makes the
    /// paper's Fig. 3/12 IVF tail so heavy).
    pub thrash_bps: f64,
    /// In-memory similarity-scan rate (bytes of embeddings per second).
    pub mem_scan_bps: f64,
    /// LLM prefill rate, prompt tokens per second.
    pub prefill_tokens_per_sec: f64,
    /// Average characters per token for the corpus/LLM tokenizer.
    pub chars_per_token: f64,
}

impl DeviceProfile {
    /// The paper's testbed (Jetson Orin Nano, Table 3) at 1:100 data scale.
    pub fn jetson_orin_nano() -> Self {
        DeviceProfile {
            name: "jetson-orin-nano-1:100".into(),
            // 48 MiB represents the 8 GiB device; the LLM working set
            // (Sheared-LLaMA-2.7B fp16 + KV + runtime ≈ 5.4 GiB, i.e.
            // ~2/3 of device RAM) takes 32 MiB, leaving a 16 MiB index
            // budget — the same proportions as the paper's testbed, which
            // classify Table 2 exactly (quora lands at the "nearly
            // exceeds memory" boundary §6.3.4 describes).
            mem_total_bytes: 48 << 20,
            llm_weight_bytes: 32 << 20,
            embed_gen_overhead_us: 1_000,
            embed_gen_chars_per_sec: 100_000.0,
            storage_seek_us: 1_000,
            storage_scatter_overhead_us: 25_000,
            storage_random_bps: 450e3, // SD UHS-I small-random
            storage_seq_bps: 20e6,     // SD UHS-I sequential
            thrash_bps: 120e3,         // mmap fault storms under pressure
            mem_scan_bps: 2e9,
            prefill_tokens_per_sec: 1_200.0,
            chars_per_token: 4.0,
        }
    }

    /// A hypothetical NVMe-equipped edge box — used by the storage-
    /// sensitivity ablation (EXPERIMENTS.md §Ablations).
    pub fn edge_nvme() -> Self {
        DeviceProfile {
            name: "edge-nvme-1:100".into(),
            storage_seek_us: 100,
            storage_scatter_overhead_us: 400,
            storage_random_bps: 40e6,
            storage_seq_bps: 600e6,
            thrash_bps: 10e6,
            ..Self::jetson_orin_nano()
        }
    }

    /// A server-class reference (Nvidia L40 row of Table 1): everything
    /// fits, nothing thrashes — the contrast row for Fig. 3.
    pub fn server_l40() -> Self {
        DeviceProfile {
            name: "server-l40-1:100".into(),
            mem_total_bytes: 384 << 20,
            embed_gen_chars_per_sec: 2e6,
            prefill_tokens_per_sec: 20_000.0,
            storage_seek_us: 50,
            storage_scatter_overhead_us: 200,
            storage_random_bps: 100e6,
            storage_seq_bps: 2e9,
            thrash_bps: 50e6,
            ..Self::jetson_orin_nano()
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name {
            "jetson" | "jetson-orin-nano" => Some(Self::jetson_orin_nano()),
            "nvme" | "edge-nvme" => Some(Self::edge_nvme()),
            "server" | "server-l40" => Some(Self::server_l40()),
            _ => None,
        }
    }

    pub fn embed_gen_overhead(&self) -> SimDuration {
        SimDuration::from_micros(self.embed_gen_overhead_us)
    }

    pub fn storage_seek(&self) -> SimDuration {
        SimDuration::from_micros(self.storage_seek_us)
    }

    /// Modeled cost of generating embeddings for `chars` characters of text.
    pub fn embed_gen_cost(&self, chars: u64) -> SimDuration {
        self.embed_gen_overhead()
            + SimDuration::from_secs_f64(chars as f64 / self.embed_gen_chars_per_sec)
    }

    /// Modeled cost of a storage read. Contiguous blobs (precomputed tail
    /// clusters, sequential flat-scan pages, LLM weight reloads) stream at
    /// sequential bandwidth after one seek; scattered reads (paged-out
    /// cluster embeddings) pay the scatter overhead plus random-IO rate.
    pub fn storage_read_cost(&self, bytes: u64, contiguous: bool) -> SimDuration {
        if contiguous {
            self.storage_seek()
                + SimDuration::from_secs_f64(bytes as f64 / self.storage_seq_bps)
        } else {
            SimDuration::from_micros(self.storage_scatter_overhead_us)
                + SimDuration::from_secs_f64(bytes as f64 / self.storage_random_bps)
        }
    }

    /// Modeled cost of faulting `bytes` back in under memory pressure
    /// (thrash): mmap fault storms, page-cache churn, write-back
    /// interference. Strictly worse than a clean scattered read.
    pub fn thrash_cost(&self, bytes: u64) -> SimDuration {
        SimDuration::from_micros(self.storage_scatter_overhead_us)
            + SimDuration::from_secs_f64(bytes as f64 / self.thrash_bps)
    }

    /// Modeled cost of an in-memory similarity scan over `bytes` of
    /// embeddings.
    pub fn mem_scan_cost(&self, bytes: u64) -> SimDuration {
        SimDuration::from_secs_f64(bytes as f64 / self.mem_scan_bps)
    }

    /// Modeled LLM prefill cost for a prompt of `tokens`.
    pub fn prefill_cost(&self, tokens: u64) -> SimDuration {
        SimDuration::from_secs_f64(tokens as f64 / self.prefill_tokens_per_sec)
    }

    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("name", Value::str(&self.name)),
            ("mem_total_bytes", self.mem_total_bytes.into()),
            ("llm_weight_bytes", self.llm_weight_bytes.into()),
            ("embed_gen_overhead_us", self.embed_gen_overhead_us.into()),
            ("embed_gen_chars_per_sec", self.embed_gen_chars_per_sec.into()),
            ("storage_seek_us", self.storage_seek_us.into()),
            (
                "storage_scatter_overhead_us",
                self.storage_scatter_overhead_us.into(),
            ),
            ("storage_random_bps", self.storage_random_bps.into()),
            ("storage_seq_bps", self.storage_seq_bps.into()),
            ("thrash_bps", self.thrash_bps.into()),
            ("mem_scan_bps", self.mem_scan_bps.into()),
            ("prefill_tokens_per_sec", self.prefill_tokens_per_sec.into()),
            ("chars_per_token", self.chars_per_token.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(DeviceProfile {
            name: v.req("name")?.as_str().context("name")?.into(),
            mem_total_bytes: v.req("mem_total_bytes")?.as_u64().context("mem")?,
            llm_weight_bytes: v.req("llm_weight_bytes")?.as_u64().context("llm")?,
            embed_gen_overhead_us: v
                .req("embed_gen_overhead_us")?
                .as_u64()
                .context("overhead")?,
            embed_gen_chars_per_sec: v
                .req("embed_gen_chars_per_sec")?
                .as_f64()
                .context("gen rate")?,
            storage_seek_us: v.req("storage_seek_us")?.as_u64().context("seek")?,
            storage_scatter_overhead_us: v
                .req("storage_scatter_overhead_us")?
                .as_u64()
                .context("scatter")?,
            storage_random_bps: v.req("storage_random_bps")?.as_f64().context("rbps")?,
            storage_seq_bps: v.req("storage_seq_bps")?.as_f64().context("sbps")?,
            thrash_bps: v.req("thrash_bps")?.as_f64().context("thrash")?,
            mem_scan_bps: v.req("mem_scan_bps")?.as_f64().context("scan")?,
            prefill_tokens_per_sec: v
                .req("prefill_tokens_per_sec")?
                .as_f64()
                .context("prefill")?,
            chars_per_token: v.req("chars_per_token")?.as_f64().context("cpt")?,
        })
    }
}

/// One evaluated dataset (Table 2), scaled 1:100 in record count while
/// keeping per-cluster text sizes paper-scale (DESIGN.md §3).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetProfile {
    pub name: String,
    /// Number of data chunks (≈ records at this scale).
    pub n_chunks: usize,
    /// Number of queries in the evaluation workload.
    pub n_queries: usize,
    /// Target cluster-access reuse ratio (Table 2: total/unique accesses).
    pub reuse_ratio: f64,
    /// Number of topic groups in the generative corpus model; controls how
    /// many natural clusters exist.
    pub n_topics: usize,
    /// Mean characters per chunk.
    pub chunk_chars_mean: usize,
    /// Lognormal sigma of topic (→ cluster) sizes; ~1.0 gives the paper's
    /// tail-heavy Fig. 5 shape.
    pub cluster_sigma: f64,
    /// Retrieval SLO for this dataset (paper §6.2: 1 s small, 1.5 s large).
    pub slo_ms: u64,
    /// Corpus-generator seed (workloads are fully deterministic).
    pub seed: u64,
    /// Per-dataset nprobe, tuned (paper §6.2) to normalize recall against
    /// the flat baseline (`edgerag tune --dataset X` re-derives it).
    pub nprobe: usize,
}

impl DatasetProfile {
    pub fn slo(&self) -> SimDuration {
        SimDuration::from_millis(self.slo_ms)
    }

    /// Approximate embedding-database size for this dataset (dim f32).
    pub fn embedding_bytes(&self, dim: usize) -> u64 {
        (self.n_chunks * dim * 4) as u64
    }

    /// The six BEIR-suite profiles of Table 2 at 1:100 scale.
    pub fn beir_suite() -> Vec<DatasetProfile> {
        vec![
            DatasetProfile {
                name: "scidocs".into(),
                n_chunks: 2_000,
                n_queries: 200,
                reuse_ratio: 1.73,
                n_topics: 120,
                chunk_chars_mean: 256,
                cluster_sigma: 1.2,
                slo_ms: 1_000,
                seed: 101,
                nprobe: 8,
            },
            DatasetProfile {
                name: "fiqa".into(),
                n_chunks: 6_000,
                n_queries: 1_329,
                reuse_ratio: 4.47,
                n_topics: 360,
                chunk_chars_mean: 256,
                cluster_sigma: 1.2,
                slo_ms: 1_000,
                seed: 102,
                nprobe: 8,
            },
            DatasetProfile {
                name: "quora".into(),
                n_chunks: 16_000,
                n_queries: 3_000,
                reuse_ratio: 1.91,
                n_topics: 1_000,
                chunk_chars_mean: 160,
                cluster_sigma: 1.2,
                slo_ms: 1_000,
                seed: 103,
                nprobe: 12,
            },
            DatasetProfile {
                name: "nq".into(),
                n_chunks: 40_000,
                n_queries: 1_024,
                reuse_ratio: 1.25,
                n_topics: 2_400,
                chunk_chars_mean: 256,
                cluster_sigma: 1.2,
                slo_ms: 1_500,
                seed: 104,
                nprobe: 16,
            },
            DatasetProfile {
                name: "hotpotqa".into(),
                n_chunks: 64_000,
                n_queries: 2_210,
                reuse_ratio: 1.42,
                n_topics: 3_900,
                chunk_chars_mean: 256,
                cluster_sigma: 1.2,
                slo_ms: 1_500,
                seed: 105,
                nprobe: 24,
            },
            DatasetProfile {
                name: "fever".into(),
                n_chunks: 72_000,
                n_queries: 1_392,
                reuse_ratio: 2.41,
                n_topics: 4_360,
                chunk_chars_mean: 288,
                cluster_sigma: 1.3,
                slo_ms: 1_500,
                seed: 106,
                nprobe: 24,
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<DatasetProfile> {
        if name == "tiny" {
            return Some(Self::tiny());
        }
        Self::beir_suite().into_iter().find(|d| d.name == name)
    }

    /// A tiny profile for tests and the quickstart example.
    pub fn tiny() -> DatasetProfile {
        DatasetProfile {
            name: "tiny".into(),
            n_chunks: 512,
            n_queries: 64,
            reuse_ratio: 2.0,
            n_topics: 8,
            chunk_chars_mean: 200,
            cluster_sigma: 0.8,
            slo_ms: 1_000,
            seed: 7,
            nprobe: 4,
        }
    }

    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("name", Value::str(&self.name)),
            ("n_chunks", self.n_chunks.into()),
            ("n_queries", self.n_queries.into()),
            ("reuse_ratio", self.reuse_ratio.into()),
            ("n_topics", self.n_topics.into()),
            ("chunk_chars_mean", self.chunk_chars_mean.into()),
            ("cluster_sigma", self.cluster_sigma.into()),
            ("slo_ms", self.slo_ms.into()),
            ("seed", self.seed.into()),
            ("nprobe", self.nprobe.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(DatasetProfile {
            name: v.req("name")?.as_str().context("name")?.into(),
            n_chunks: v.req("n_chunks")?.as_usize().context("n_chunks")?,
            n_queries: v.req("n_queries")?.as_usize().context("n_queries")?,
            reuse_ratio: v.req("reuse_ratio")?.as_f64().context("reuse")?,
            n_topics: v.req("n_topics")?.as_usize().context("topics")?,
            chunk_chars_mean: v
                .req("chunk_chars_mean")?
                .as_usize()
                .context("chunk chars")?,
            cluster_sigma: v.req("cluster_sigma")?.as_f64().context("sigma")?,
            slo_ms: v.req("slo_ms")?.as_u64().context("slo")?,
            seed: v.req("seed")?.as_u64().context("seed")?,
            nprobe: v.req("nprobe")?.as_usize().context("nprobe")?,
        })
    }
}

/// Which of the paper's five evaluated index configurations (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IndexKind {
    /// Linear scan of all embeddings, all in memory.
    Flat,
    /// Two-level IVF, both levels' embeddings in memory.
    Ivf,
    /// Two-level, second level pruned, embeddings generated online.
    IvfGen,
    /// + heavy tail clusters precomputed and loaded from storage.
    IvfGenLoad,
    /// + cost-aware adaptive caching — the full EdgeRAG system.
    EdgeRag,
}

impl IndexKind {
    pub const ALL: [IndexKind; 5] = [
        IndexKind::Flat,
        IndexKind::Ivf,
        IndexKind::IvfGen,
        IndexKind::IvfGenLoad,
        IndexKind::EdgeRag,
    ];

    pub fn name(self) -> &'static str {
        match self {
            IndexKind::Flat => "flat",
            IndexKind::Ivf => "ivf",
            IndexKind::IvfGen => "ivf+gen",
            IndexKind::IvfGenLoad => "ivf+gen+load",
            IndexKind::EdgeRag => "edgerag",
        }
    }

    pub fn by_name(name: &str) -> Option<IndexKind> {
        Self::ALL.into_iter().find(|k| k.name() == name)
    }

    pub fn uses_storage(self) -> bool {
        matches!(self, IndexKind::IvfGenLoad | IndexKind::EdgeRag)
    }

    pub fn uses_cache(self) -> bool {
        matches!(self, IndexKind::EdgeRag)
    }
}

/// Retrieval / serving parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct RetrievalConfig {
    /// Clusters probed per query (IVF nprobe). Tuned per dataset to
    /// normalize recall against the flat baseline (paper §6.2).
    pub nprobe: usize,
    /// Data chunks returned to the LLM.
    pub top_k: usize,
    /// Embedding-cache capacity in bytes (paper: ≈7% of system memory).
    pub cache_capacity_bytes: u64,
    /// Cost-aware LFU decay factor (Alg. 2).
    pub cache_decay: f64,
    /// Adaptive-threshold step in milliseconds (Alg. 3 `++`/`--`).
    pub threshold_step_ms: f64,
    /// EWMA alpha for the moving-average latency (Alg. 3).
    pub latency_ewma_alpha: f64,
    /// Selective-storage limit as a fraction of the dataset SLO: clusters
    /// whose gen cost exceeds `store_slo_fraction × SLO` are precomputed.
    pub store_slo_fraction: f64,
    /// Max prompt tokens fed to the LLM (query + retrieved chunks).
    pub max_prompt_tokens: usize,
    /// Index shards for the EdgeRAG-family configurations: clusters are
    /// partitioned round-robin across this many independently locked
    /// shards so probes fan out and structural updates stall only the
    /// owning shard (see `docs/ARCHITECTURE.md`).
    ///
    /// * `1` (the library default) — the single [`crate::index::EdgeIndex`],
    ///   bit-identical to the paper-exact reproduction path.
    /// * `0` — auto: one shard per available core (what `edgerag serve`
    ///   defaults to via `--shards`).
    /// * `n > 1` — exactly `n` shards; the cache budget is split evenly.
    pub shards: usize,
    /// Cross-query batch scheduling (`crate::sched`): concurrent queries'
    /// embedding and centroid-probe kernel calls coalesce into fused
    /// batches. **Off by default** — the library serves the paper-exact
    /// unbatched path; `edgerag serve` turns it on (results are
    /// bit-identical either way, verified by
    /// `rust/tests/sched_equivalence.rs`).
    pub batching: bool,
    /// Batch-window deadline in µs: the oldest queued work item waits at
    /// most this long before its partial batch executes. Only meaningful
    /// with `batching`.
    pub batch_window_us: u64,
    /// Queries admitted concurrently by the batch scheduler (and the
    /// server's admission queue bound); beyond it requests are rejected
    /// with an "overloaded" error. 0 = unlimited.
    pub max_inflight: usize,
    /// Online cross-shard rebalancing: when the round-robin placement
    /// drifts under inserts/splits (EdgeRAG's cluster sizes are heavily
    /// skewed), hot clusters migrate between shards one at a time without
    /// stopping concurrent searches. **Off by default** — the library
    /// keeps the static placement; `edgerag serve` turns it on. Only
    /// meaningful with `shards > 1`.
    pub rebalance: bool,
    /// Run one rebalance round after every this many structural updates
    /// (inserts + removes). Only meaningful with `rebalance`; an explicit
    /// `{"op":"rebalance"}` server op triggers a round regardless.
    pub rebalance_interval_ops: usize,
    /// Cluster migrations allowed per rebalance round — bounds how much
    /// copy/flip/retire work a single round may impose on the serving
    /// path.
    pub max_migrations_per_round: usize,
    /// Halve every per-cluster probe-heat counter (and co-probe affinity
    /// edge) after every this many structural updates, so the heat the
    /// placement planner scores on tracks *current* traffic instead of
    /// lifetime totals (a historical hot spot decays away within a few
    /// intervals). 0 disables decay — counters become monotone lifetime
    /// totals again. Heat is observational only: decay never changes
    /// search results.
    pub heat_decay_interval_ops: usize,
    /// Floor for the elastic shard count: a server `reshard` op clamps
    /// its target to at least this many shards (`--shards-min`). The
    /// library APIs (`grow_shards`/`shrink_shards`) are not clamped —
    /// the bound is serving policy, not an index invariant.
    pub shards_min: usize,
    /// Ceiling for the elastic shard count (`--shards-max`); also sizes
    /// the shard worker pool so a later grow has workers waiting. 0 (the
    /// default) means "no configured ceiling" — the hard
    /// [`crate::index::shard::MAX_SHARDS`] limit still applies.
    pub shards_max: usize,
    /// Structural write-ahead log: every insert/remove/migrate/threshold
    /// op is journalled before its irreversible mutation and replayed on
    /// startup (`docs/ARCHITECTURE.md` § Durability). **Off by default**
    /// — the library stays ephemeral and byte-for-byte unchanged;
    /// `edgerag serve` turns it on.
    pub wal: bool,
    /// Consolidate the WAL into its snapshot (and truncate the live log)
    /// after every this many appended records. 0 disables periodic
    /// snapshots — the log then only compacts on clean shutdown.
    pub snapshot_interval_ops: usize,
    /// Query-scoped tracing: each served query/insert records a span
    /// tree (queue wait, fused-batch shares, per-shard walks, WAL
    /// appends) into bounded in-memory rings, queryable via the server's
    /// `trace` op. **Off by default** — the untraced hot path pays one
    /// relaxed atomic load per potential span and allocates nothing;
    /// `edgerag serve` turns it on. Purely observational: results are
    /// bit-identical either way.
    pub trace: bool,
    /// Slow-query threshold in µs: traced queries at or above it are
    /// always captured into the slow-query ring (the sampling ring wraps
    /// much sooner). Only meaningful with `trace`.
    pub slow_query_us: u64,
    /// Per-query deadline in µs, stamped at server admission. A query
    /// whose deadline has already expired when it is dequeued — by a
    /// server worker, or inside a batch stage — is shed with a distinct
    /// "deadline exceeded" error instead of executed, and the batch
    /// scheduler closes partial batches no later than their earliest
    /// rider's deadline. `0` (the default) derives the deadline as
    /// `4 × slow_query_us`; a very large value effectively disables
    /// shedding (the stamp saturates and never expires).
    pub deadline_us: u64,
}

/// One shard per available core, clamped to a sensible serving range —
/// the `shards: 0` ("auto") resolution and the `edgerag serve` default.
pub fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

impl Default for RetrievalConfig {
    fn default() -> Self {
        RetrievalConfig {
            nprobe: 8,
            top_k: 5,
            cache_capacity_bytes: 4 << 20, // ≈7% of the 64 MiB budget
            cache_decay: 0.9,
            threshold_step_ms: 2.0,
            latency_ewma_alpha: 0.2,
            store_slo_fraction: 0.33,
            max_prompt_tokens: 2048,
            shards: 1,
            batching: false,
            batch_window_us: 200,
            max_inflight: 256,
            rebalance: false,
            rebalance_interval_ops: 128,
            max_migrations_per_round: 4,
            heat_decay_interval_ops: 1024,
            shards_min: 1,
            shards_max: 0,
            wal: false,
            snapshot_interval_ops: 512,
            trace: false,
            slow_query_us: 100_000,
            deadline_us: 0,
        }
    }
}

impl RetrievalConfig {
    /// The effective shard count: `shards` itself, or one per core when 0.
    pub fn resolved_shards(&self) -> usize {
        match self.shards {
            0 => default_shards(),
            n => n,
        }
    }

    /// The effective per-query deadline in µs: `deadline_us` itself, or
    /// `4 × slow_query_us` when 0 — a query four times over the slow
    /// threshold is past saving, so shedding it frees capacity for
    /// queries that can still meet their latency target.
    ///
    /// A derived deadline of 0 means **disarmed**, never "shed
    /// immediately": running with `--slow-query-us 0` (keep-every-trace
    /// mode) would otherwise derive a 0 µs budget that sheds every
    /// query at admission. Both the server's deadline stamp and the
    /// batch scheduler already treat 0 as "no deadline"; this makes the
    /// derivation honor the same contract explicitly.
    pub fn resolved_deadline_us(&self) -> u64 {
        match (self.deadline_us, self.slow_query_us) {
            (0, 0) => 0, // keep-all tracing: shedding disarmed
            (0, slow) => slow.saturating_mul(4),
            (n, _) => n,
        }
    }

    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("nprobe", self.nprobe.into()),
            ("top_k", self.top_k.into()),
            ("cache_capacity_bytes", self.cache_capacity_bytes.into()),
            ("cache_decay", self.cache_decay.into()),
            ("threshold_step_ms", self.threshold_step_ms.into()),
            ("latency_ewma_alpha", self.latency_ewma_alpha.into()),
            ("store_slo_fraction", self.store_slo_fraction.into()),
            ("max_prompt_tokens", self.max_prompt_tokens.into()),
            ("shards", self.shards.into()),
            ("batching", self.batching.into()),
            ("batch_window_us", self.batch_window_us.into()),
            ("max_inflight", self.max_inflight.into()),
            ("rebalance", self.rebalance.into()),
            (
                "rebalance_interval_ops",
                self.rebalance_interval_ops.into(),
            ),
            (
                "max_migrations_per_round",
                self.max_migrations_per_round.into(),
            ),
            (
                "heat_decay_interval_ops",
                self.heat_decay_interval_ops.into(),
            ),
            ("shards_min", self.shards_min.into()),
            ("shards_max", self.shards_max.into()),
            ("wal", self.wal.into()),
            (
                "snapshot_interval_ops",
                self.snapshot_interval_ops.into(),
            ),
            ("trace", self.trace.into()),
            ("slow_query_us", self.slow_query_us.into()),
            ("deadline_us", self.deadline_us.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(RetrievalConfig {
            nprobe: v.req("nprobe")?.as_usize().context("nprobe")?,
            top_k: v.req("top_k")?.as_usize().context("top_k")?,
            cache_capacity_bytes: v
                .req("cache_capacity_bytes")?
                .as_u64()
                .context("cache cap")?,
            cache_decay: v.req("cache_decay")?.as_f64().context("decay")?,
            threshold_step_ms: v.req("threshold_step_ms")?.as_f64().context("step")?,
            latency_ewma_alpha: v
                .req("latency_ewma_alpha")?
                .as_f64()
                .context("alpha")?,
            store_slo_fraction: v
                .req("store_slo_fraction")?
                .as_f64()
                .context("fraction")?,
            max_prompt_tokens: v
                .req("max_prompt_tokens")?
                .as_usize()
                .context("prompt tokens")?,
            // Optional for configs written before sharding existed.
            shards: match v.get("shards") {
                Some(s) => s.as_usize().context("shards")?,
                None => 1,
            },
            // Optional for configs written before cross-query batching.
            batching: match v.get("batching") {
                Some(b) => b.as_bool().context("batching")?,
                None => false,
            },
            batch_window_us: match v.get("batch_window_us") {
                Some(w) => w.as_u64().context("batch_window_us")?,
                None => 200,
            },
            max_inflight: match v.get("max_inflight") {
                Some(m) => m.as_usize().context("max_inflight")?,
                None => 256,
            },
            // Optional for configs written before online rebalancing.
            rebalance: match v.get("rebalance") {
                Some(b) => b.as_bool().context("rebalance")?,
                None => false,
            },
            rebalance_interval_ops: match v.get("rebalance_interval_ops") {
                Some(n) => n.as_usize().context("rebalance_interval_ops")?,
                None => 128,
            },
            max_migrations_per_round: match v.get("max_migrations_per_round") {
                Some(n) => n.as_usize().context("max_migrations_per_round")?,
                None => 4,
            },
            // Optional for configs written before heat-aware placement
            // and the elastic shard topology.
            heat_decay_interval_ops: match v.get("heat_decay_interval_ops") {
                Some(n) => n.as_usize().context("heat_decay_interval_ops")?,
                None => 1024,
            },
            shards_min: match v.get("shards_min") {
                Some(n) => n.as_usize().context("shards_min")?,
                None => 1,
            },
            shards_max: match v.get("shards_max") {
                Some(n) => n.as_usize().context("shards_max")?,
                None => 0,
            },
            // Optional for configs written before the structural WAL.
            wal: match v.get("wal") {
                Some(b) => b.as_bool().context("wal")?,
                None => false,
            },
            snapshot_interval_ops: match v.get("snapshot_interval_ops") {
                Some(n) => n.as_usize().context("snapshot_interval_ops")?,
                None => 512,
            },
            // Optional for configs written before query-scoped tracing.
            trace: match v.get("trace") {
                Some(b) => b.as_bool().context("trace")?,
                None => false,
            },
            slow_query_us: match v.get("slow_query_us") {
                Some(n) => n.as_u64().context("slow_query_us")?,
                None => 100_000,
            },
            // Optional for configs written before deadline-aware serving.
            deadline_us: match v.get("deadline_us") {
                Some(n) => n.as_u64().context("deadline_us")?,
                None => 0,
            },
        })
    }
}

/// Top-level config: what `edgerag serve`/`edgerag bench` load from JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub device: DeviceProfile,
    pub dataset: DatasetProfile,
    pub index: IndexKind,
    pub retrieval: RetrievalConfig,
    /// Directory holding AOT artifacts (manifest.json etc.).
    pub artifacts_dir: String,
    /// Directory for on-disk index state (blob store).
    pub state_dir: String,
}

impl SystemConfig {
    pub fn new(dataset: DatasetProfile, index: IndexKind) -> Self {
        SystemConfig {
            device: DeviceProfile::jetson_orin_nano(),
            dataset,
            index,
            retrieval: RetrievalConfig::default(),
            artifacts_dir: "artifacts".into(),
            state_dir: "target/edgerag-state".into(),
        }
    }

    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("device", self.device.to_json()),
            ("dataset", self.dataset.to_json()),
            ("index", Value::str(self.index.name())),
            ("retrieval", self.retrieval.to_json()),
            ("artifacts_dir", Value::str(&self.artifacts_dir)),
            ("state_dir", Value::str(&self.state_dir)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let index_name = v.req("index")?.as_str().context("index")?;
        Ok(SystemConfig {
            device: DeviceProfile::from_json(v.req("device")?)?,
            dataset: DatasetProfile::from_json(v.req("dataset")?)?,
            index: IndexKind::by_name(index_name)
                .with_context(|| format!("unknown index kind `{index_name}`"))?,
            retrieval: RetrievalConfig::from_json(v.req("retrieval")?)?,
            artifacts_dir: v.req("artifacts_dir")?.as_str().context("dir")?.into(),
            state_dir: v.req("state_dir")?.as_str().context("state")?.into(),
        })
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_vs_load_crossover_matches_fig4() {
        // Paper Fig. 4: generating embeddings for clusters below ~24 kB of
        // text is faster than loading their (scattered) embeddings.
        let d = DeviceProfile::jetson_orin_nano();
        let emb_bytes = |chars: u64| chars / 256 * 1024; // 1 KiB per 256-char chunk
        let small = 12_000u64;
        let big = 48_000u64;
        assert!(d.embed_gen_cost(small) < d.storage_read_cost(emb_bytes(small), false));
        assert!(d.embed_gen_cost(big) > d.storage_read_cost(emb_bytes(big), false));
    }

    #[test]
    fn tail_cluster_sequential_load_beats_generation() {
        // Why selective storage works: a 600 kB-of-text tail cluster takes
        // seconds to generate but loads fast as one contiguous blob.
        let d = DeviceProfile::jetson_orin_nano();
        let chars = 600_000u64;
        let bytes = chars / 256 * 1024;
        let gen = d.embed_gen_cost(chars);
        let load = d.storage_read_cost(bytes, true);
        assert!(gen.as_millis() > 2_000, "gen = {gen}");
        assert!(load < gen, "load {load} !< gen {gen}");
        assert!(gen.as_nanos() / load.as_nanos().max(1) >= 4);
    }

    #[test]
    fn table2_memory_fit_classification() {
        // Table 2 "Fit in Dev. Mem": scidocs/fiqa/quora fit, nq/hotpotqa/
        // fever do not (after the LLM's resident share).
        let d = DeviceProfile::jetson_orin_nano();
        let budget = d.mem_total_bytes - d.llm_weight_bytes;
        for ds in DatasetProfile::beir_suite() {
            let fits = ds.embedding_bytes(256) <= budget;
            let expect = matches!(ds.name.as_str(), "scidocs" | "fiqa" | "quora");
            assert_eq!(fits, expect, "{} fits={}", ds.name, fits);
        }
    }

    #[test]
    fn beir_suite_matches_table2_ordering() {
        let suite = DatasetProfile::beir_suite();
        assert_eq!(suite.len(), 6);
        // embedding sizes must preserve the paper's ordering
        let sizes: Vec<u64> = suite.iter().map(|d| d.embedding_bytes(256)).collect();
        for w in sizes.windows(2).take(4) {
            assert!(w[0] < w[1], "sizes not increasing: {sizes:?}");
        }
        // fever > hotpotqa in embedding bytes (Table 2: 18.5 GB > 15.4 GB)
        let fever = suite.iter().find(|d| d.name == "fever").unwrap();
        let hotpot = suite.iter().find(|d| d.name == "hotpotqa").unwrap();
        assert!(fever.embedding_bytes(256) > hotpot.embedding_bytes(256));
    }

    #[test]
    fn json_roundtrip() {
        let cfg = SystemConfig::new(DatasetProfile::tiny(), IndexKind::EdgeRag);
        let text = cfg.to_json().pretty();
        let back = SystemConfig::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn deadline_derivation_disarms_with_zero_slow_query() {
        // --slow-query-us 0 (keep-all tracing) must not derive a 0 µs
        // deadline that sheds every query: the derived deadline is
        // disarmed (0 = no shedding, the contract both the server stamp
        // and the batch scheduler already implement for 0).
        let mut r = RetrievalConfig {
            deadline_us: 0,
            slow_query_us: 0,
            ..Default::default()
        };
        assert_eq!(r.resolved_deadline_us(), 0);
        // The ordinary derivation is untouched…
        r.slow_query_us = 25_000;
        assert_eq!(r.resolved_deadline_us(), 100_000);
        // …and an explicit deadline always wins, even with
        // slow_query_us = 0.
        r.deadline_us = 7_500;
        r.slow_query_us = 0;
        assert_eq!(r.resolved_deadline_us(), 7_500);
    }

    #[test]
    fn retrieval_json_back_compat_defaults_new_knobs() {
        // A config written before heat-aware placement parses with the
        // documented defaults for the new knobs.
        let mut v = RetrievalConfig::default().to_json();
        if let Value::Object(obj) = &mut v {
            obj.remove("heat_decay_interval_ops");
            obj.remove("shards_min");
            obj.remove("shards_max");
        } else {
            panic!("retrieval config serializes to an object");
        }
        let back = RetrievalConfig::from_json(&v).unwrap();
        assert_eq!(back.heat_decay_interval_ops, 1024);
        assert_eq!(back.shards_min, 1);
        assert_eq!(back.shards_max, 0);
    }

    #[test]
    fn index_kind_names_roundtrip() {
        for k in IndexKind::ALL {
            assert_eq!(IndexKind::by_name(k.name()), Some(k));
        }
        assert_eq!(IndexKind::by_name("nope"), None);
    }

    #[test]
    fn prefill_cost_linear() {
        let d = DeviceProfile::jetson_orin_nano();
        let a = d.prefill_cost(600);
        let b = d.prefill_cost(1200);
        assert_eq!(b.as_nanos(), 2 * a.as_nanos());
        assert_eq!(a.as_millis(), 500);
    }

    #[test]
    fn device_by_name() {
        assert!(DeviceProfile::by_name("jetson").is_some());
        assert!(DeviceProfile::by_name("nvme").is_some());
        assert!(DeviceProfile::by_name("server").is_some());
        assert!(DeviceProfile::by_name("x").is_none());
    }
}
