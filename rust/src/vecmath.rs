//! Dense vector storage + small CPU-side helpers.
//!
//! The heavy scoring math runs through the PJRT executables (Pallas
//! similarity kernel); this module provides the host-side containers and
//! the cheap glue (top-k selection, normalization checks, reference dot
//! products for tests).

/// A row-major matrix of embeddings (n × dim, f32).
#[derive(Debug, Clone, Default)]
pub struct EmbeddingMatrix {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl EmbeddingMatrix {
    pub fn new(dim: usize) -> Self {
        EmbeddingMatrix { dim, data: Vec::new() }
    }

    pub fn with_capacity(dim: usize, rows: usize) -> Self {
        EmbeddingMatrix {
            dim,
            data: Vec::with_capacity(dim * rows),
        }
    }

    pub fn from_rows(dim: usize, rows: &[Vec<f32>]) -> Self {
        let mut m = Self::with_capacity(dim, rows.len());
        for r in rows {
            m.push(r);
        }
        m
    }

    pub fn len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.data.len() / self.dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.data.extend_from_slice(row);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn remove_row(&mut self, i: usize) {
        let start = i * self.dim;
        self.data.drain(start..start + self.dim);
    }

    /// Flat data padded with zero rows up to `rows` (bucketed PJRT calls).
    pub fn padded(&self, rows: usize) -> Vec<f32> {
        assert!(rows >= self.len());
        let mut out = Vec::with_capacity(rows * self.dim);
        out.extend_from_slice(&self.data);
        out.resize(rows * self.dim, 0.0);
        out
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.dim.max(1))
    }
}

/// Reference dot product (tests / fallbacks).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Indices + scores of the k largest entries, descending (stable on ties
/// by lower index). Scores for padded rows can be excluded by passing the
/// true `n`.
pub fn top_k(scores: &[f32], n: usize, k: usize) -> Vec<(usize, f32)> {
    let n = n.min(scores.len());
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    // Simple selection into a small sorted buffer: k is tiny (≤ tens) on
    // every call site, so this beats building a heap of n.
    let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for (i, &s) in scores[..n].iter().enumerate() {
        if best.len() < k || s > best[k - 1].1 {
            let pos = best
                .iter()
                .position(|&(_, bs)| s > bs)
                .unwrap_or(best.len());
            best.insert(pos, (i, s));
            if best.len() > k {
                best.pop();
            }
        }
    }
    best
}

/// Reduce candidate `(chunk id, score)` pairs to the final top-k,
/// preserving [`top_k`]'s lower-index tie preference over the candidate
/// order. One shared implementation so the unbatched, batched and
/// sharded merge paths cannot drift in tie-breaking (the exact property
/// the equivalence tests pin).
pub fn top_k_hits(all_hits: Vec<(u32, f32)>, k: usize) -> Vec<(u32, f32)> {
    let scores: Vec<f32> = all_hits.iter().map(|&(_, s)| s).collect();
    top_k(&scores, all_hits.len(), k)
        .into_iter()
        .map(|(i, s)| (all_hits[i].0, s))
        .collect()
}

/// argmax with index (assignment step of k-means).
pub fn argmax(scores: &[f32]) -> usize {
    let mut bi = 0;
    let mut bs = f32::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        if s > bs {
            bs = s;
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let mut m = EmbeddingMatrix::new(3);
        m.push(&[1.0, 2.0, 3.0]);
        m.push(&[4.0, 5.0, 6.0]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.bytes(), 24);
    }

    #[test]
    fn matrix_remove() {
        let mut m = EmbeddingMatrix::from_rows(2, &[vec![1., 1.], vec![2., 2.], vec![3., 3.]]);
        m.remove_row(1);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[3.0, 3.0]);
    }

    #[test]
    fn padded_appends_zero_rows() {
        let m = EmbeddingMatrix::from_rows(2, &[vec![1., 2.]]);
        let p = m.padded(3);
        assert_eq!(p, vec![1., 2., 0., 0., 0., 0.]);
    }

    #[test]
    fn top_k_orders_descending() {
        let scores = [0.1, 0.9, 0.5, 0.7];
        let t = top_k(&scores, 4, 2);
        assert_eq!(t, vec![(1, 0.9), (3, 0.7)]);
    }

    #[test]
    fn top_k_excludes_padding() {
        let scores = [0.1, 0.2, 99.0, 98.0]; // rows 2..3 are padding
        let t = top_k(&scores, 2, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 0);
    }

    #[test]
    fn top_k_k_larger_than_n() {
        let t = top_k(&[0.3, 0.1], 2, 10);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn top_k_ties_prefer_lower_index() {
        let t = top_k(&[0.5, 0.5, 0.5], 3, 2);
        assert_eq!(t[0].0, 0);
        assert_eq!(t[1].0, 1);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    /// Reference ranking: stable sort by (score desc, candidate position
    /// asc) — the total order `top_k_hits` must realize.
    fn reference_top_k(all: &[(u32, f32)], k: usize) -> Vec<(u32, f32)> {
        let mut idx: Vec<usize> = (0..all.len()).collect();
        idx.sort_by(|&a, &b| {
            all[b]
                .1
                .partial_cmp(&all[a].1)
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.into_iter().take(k).map(|i| all[i]).collect()
    }

    /// Random candidate lists with heavy score collisions (scores
    /// quantized to 8 levels so ties actually occur).
    fn random_hits(rng: &mut crate::data::Rng, n: usize) -> Vec<(u32, f32)> {
        (0..n)
            .map(|i| (i as u32, (rng.below(8) as f32) * 0.125))
            .collect()
    }

    #[test]
    fn top_k_hits_realizes_a_total_order() {
        // Property: the selection is exactly the stable
        // (score desc, position asc) order — ties are never left to
        // accident, which is what lets the unbatched, batched and
        // sharded merge paths agree bit for bit.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0x70B));
        for case in 0..300 {
            let n = rng.range(1, 40);
            let k = rng.below(12) + 1;
            let all = random_hits(&mut rng, n);
            let got = top_k_hits(all.clone(), k);
            let want = reference_top_k(&all, k);
            assert_eq!(got, want, "case {case}: {all:?} k={k}");
        }
    }

    #[test]
    fn top_k_hits_merge_is_associative() {
        // Property: reducing per-group candidate lists to their local
        // top-k, concatenating the reduced groups in group order, and
        // reducing again gives exactly the direct top-k of the full
        // concatenation. This is the algebra the sharded (and batched)
        // merge rests on: each cluster/shard may pre-reduce its
        // candidates without changing the final ranking or its ties.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0xA550C));
        for case in 0..300 {
            let groups: Vec<Vec<(u32, f32)>> = (0..rng.range(1, 5))
                .map(|_| {
                    let n = rng.below(16);
                    random_hits(&mut rng, n)
                })
                .collect();
            // Re-tag ids so candidate positions are globally unique and
            // group order is visible in the ids.
            let mut next = 0u32;
            let groups: Vec<Vec<(u32, f32)>> = groups
                .into_iter()
                .map(|g| {
                    g.into_iter()
                        .map(|(_, s)| {
                            next += 1;
                            (next, s)
                        })
                        .collect()
                })
                .collect();
            let k = rng.below(8) + 1;
            let direct: Vec<(u32, f32)> =
                top_k_hits(groups.iter().flatten().copied().collect(), k);
            let staged: Vec<(u32, f32)> = top_k_hits(
                groups
                    .iter()
                    .flat_map(|g| top_k_hits(g.clone(), k))
                    .collect(),
                k,
            );
            assert_eq!(direct, staged, "case {case}: {groups:?} k={k}");
        }
    }
}
