//! Dense vector storage + the CPU-side kernel primitives.
//!
//! The heavy scoring math runs through the PJRT executables (Pallas
//! similarity kernel) when artifacts are available; everything else —
//! the reference backend's similarity/projection kernels, centroid
//! probing, top-k selection — bottoms out in this module. [`dot`] is the
//! *single shared* dot product for every path (oracle, sharded,
//! batched), so its reduction order is a determinism contract: all the
//! bit-equality suites compare results that flowed through the same
//! lanes.

/// A row-major matrix of embeddings (n × dim, f32).
#[derive(Debug, Clone, Default)]
pub struct EmbeddingMatrix {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl EmbeddingMatrix {
    pub fn new(dim: usize) -> Self {
        EmbeddingMatrix { dim, data: Vec::new() }
    }

    pub fn with_capacity(dim: usize, rows: usize) -> Self {
        EmbeddingMatrix {
            dim,
            data: Vec::with_capacity(dim * rows),
        }
    }

    pub fn from_rows(dim: usize, rows: &[Vec<f32>]) -> Self {
        let mut m = Self::with_capacity(dim, rows.len());
        for r in rows {
            m.push(r);
        }
        m
    }

    pub fn len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.data.len() / self.dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.data.extend_from_slice(row);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn remove_row(&mut self, i: usize) {
        let start = i * self.dim;
        self.data.drain(start..start + self.dim);
    }

    /// Flat data padded with zero rows up to `rows` (bucketed PJRT calls).
    pub fn padded(&self, rows: usize) -> Vec<f32> {
        assert!(rows >= self.len());
        let mut out = Vec::with_capacity(rows * self.dim);
        out.extend_from_slice(&self.data);
        out.resize(rows * self.dim, 0.0);
        out
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.dim.max(1))
    }
}

/// Number of independent accumulator lanes in [`dot`]. Part of the
/// determinism contract: changing it changes every f32 score in the
/// system at the ulp level, so the golden files would need regeneration.
pub const DOT_LANES: usize = 8;

/// Dot product over a fixed 8-lane strided accumulator.
///
/// Element `i` always lands in lane `i % 8` and the lanes are combined
/// in a fixed pairwise tree, so the reduction order — and therefore the
/// exact f32 result — depends only on the input length, never on the
/// call site. The lane structure has no data dependence between
/// consecutive elements, which is what lets LLVM keep 8 multiplies in
/// flight (and auto-vectorize to whatever SIMD width the target has)
/// where the retired sequential fold ([`dot_scalar`]) serialized on one
/// accumulator.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; DOT_LANES];
    let ac = a.chunks_exact(DOT_LANES);
    let bc = b.chunks_exact(DOT_LANES);
    let (ar, br) = (ac.remainder(), bc.remainder());
    for (xs, ys) in ac.zip(bc) {
        for l in 0..DOT_LANES {
            lanes[l] += xs[l] * ys[l];
        }
    }
    // Scalar tail: fewer than 8 trailing elements, each still in its own
    // lane slot (index `len - tail + l` maps to lane `l` because the
    // chunked prefix length is a multiple of DOT_LANES).
    for (l, (x, y)) in ar.iter().zip(br).enumerate() {
        lanes[l] += x * y;
    }
    // Fixed pairwise reduction tree — NOT a left fold. This order is
    // load-bearing for bit-equality across call paths.
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

/// The retired sequential dot product (single left-fold accumulator).
/// Kept as the scalar A/B baseline for `micro_hotpath` and as the model
/// the SIMD property tests measure drift against. Not used on any
/// serving path.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `out[i] += alpha * x[i]`, 8-wide unrolled. Each output element sees
/// exactly one fused-free multiply-add per call, in the same order as
/// the naive loop, so this is *bit-identical* to the scalar form — the
/// unroll only removes the loop-carried bookkeeping so the compiler can
/// vectorize the independent element updates.
pub fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let xc = x.chunks_exact(DOT_LANES);
    let tail = xc.remainder();
    let mut oc = out.chunks_exact_mut(DOT_LANES);
    for (os, xs) in (&mut oc).zip(xc) {
        for l in 0..DOT_LANES {
            os[l] += alpha * xs[l];
        }
    }
    for (o, x) in oc.into_remainder().iter_mut().zip(tail) {
        *o += alpha * x;
    }
}

pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Largest `k` the branch-light stack-buffer scan handles; larger `k`
/// takes the general heap-free selection. Every retrieval call site
/// (`final_k`, `nprobe`, k-means assignment) sits at or below this.
const TOP_K_INLINE: usize = 16;

/// Indices + scores of the k largest entries, descending (stable on ties
/// by lower index). Scores for padded rows can be excluded by passing the
/// true `n`. Scores must be NaN-free (they are: every producer is a dot
/// of finite normalized embeddings).
pub fn top_k(scores: &[f32], n: usize, k: usize) -> Vec<(usize, f32)> {
    let n = n.min(scores.len());
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    if k <= TOP_K_INLINE {
        top_k_small(&scores[..n], k)
    } else {
        top_k_select(scores, n, k)
    }
}

/// Branch-light selection for k ≤ [`TOP_K_INLINE`]: the candidate buffer
/// lives in two stack arrays (no `Vec` insert/remove shifting), the hot
/// rejection test is a single compare against the current floor, and the
/// insertion walks backward shifting at most k slots. Bit-identical to
/// [`top_k_select`] for NaN-free input: the backward walk stops at the
/// first `val[p-1] >= s`, which is exactly the forward scan's first
/// `s > val[j]` position, so ties keep their lower-index preference.
fn top_k_small(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    debug_assert!(k <= TOP_K_INLINE && k > 0);
    let mut idx = [0usize; TOP_K_INLINE];
    let mut val = [f32::NEG_INFINITY; TOP_K_INLINE];
    let mut len = 0usize;
    for (i, &s) in scores.iter().enumerate() {
        // Hot path: buffer full and s does not beat the floor (NaN-free
        // input makes `<=` the exact negation of the insert test).
        if len == k && s <= val[k - 1] {
            continue;
        }
        let insert_len = if len < k { len + 1 } else { k };
        let mut p = insert_len - 1;
        while p > 0 && s > val[p - 1] {
            val[p] = val[p - 1];
            idx[p] = idx[p - 1];
            p -= 1;
        }
        val[p] = s;
        idx[p] = i;
        len = insert_len;
    }
    (0..len).map(|j| (idx[j], val[j])).collect()
}

/// The general selection (and the retired sole implementation): sorted
/// `Vec` buffer with forward-scan insertion. Kept for k > 16 and as the
/// reference model `top_k_small`'s property tests compare against.
fn top_k_select(scores: &[f32], n: usize, k: usize) -> Vec<(usize, f32)> {
    let n = n.min(scores.len());
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    // Simple selection into a small sorted buffer: k is tiny (≤ tens) on
    // every call site, so this beats building a heap of n.
    let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for (i, &s) in scores[..n].iter().enumerate() {
        if best.len() < k || s > best[k - 1].1 {
            let pos = best
                .iter()
                .position(|&(_, bs)| s > bs)
                .unwrap_or(best.len());
            best.insert(pos, (i, s));
            if best.len() > k {
                best.pop();
            }
        }
    }
    best
}

/// Reduce candidate `(chunk id, score)` pairs to the final top-k,
/// preserving [`top_k`]'s lower-index tie preference over the candidate
/// order. One shared implementation so the unbatched, batched and
/// sharded merge paths cannot drift in tie-breaking (the exact property
/// the equivalence tests pin).
pub fn top_k_hits(all_hits: Vec<(u32, f32)>, k: usize) -> Vec<(u32, f32)> {
    let scores: Vec<f32> = all_hits.iter().map(|&(_, s)| s).collect();
    top_k(&scores, all_hits.len(), k)
        .into_iter()
        .map(|(i, s)| (all_hits[i].0, s))
        .collect()
}

/// argmax with index (assignment step of k-means).
pub fn argmax(scores: &[f32]) -> usize {
    let mut bi = 0;
    let mut bs = f32::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        if s > bs {
            bs = s;
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let mut m = EmbeddingMatrix::new(3);
        m.push(&[1.0, 2.0, 3.0]);
        m.push(&[4.0, 5.0, 6.0]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.bytes(), 24);
    }

    #[test]
    fn matrix_remove() {
        let mut m = EmbeddingMatrix::from_rows(2, &[vec![1., 1.], vec![2., 2.], vec![3., 3.]]);
        m.remove_row(1);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[3.0, 3.0]);
    }

    #[test]
    fn padded_appends_zero_rows() {
        let m = EmbeddingMatrix::from_rows(2, &[vec![1., 2.]]);
        let p = m.padded(3);
        assert_eq!(p, vec![1., 2., 0., 0., 0., 0.]);
    }

    #[test]
    fn top_k_orders_descending() {
        let scores = [0.1, 0.9, 0.5, 0.7];
        let t = top_k(&scores, 4, 2);
        assert_eq!(t, vec![(1, 0.9), (3, 0.7)]);
    }

    #[test]
    fn top_k_excludes_padding() {
        let scores = [0.1, 0.2, 99.0, 98.0]; // rows 2..3 are padding
        let t = top_k(&scores, 2, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 0);
    }

    #[test]
    fn top_k_k_larger_than_n() {
        let t = top_k(&[0.3, 0.1], 2, 10);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn top_k_ties_prefer_lower_index() {
        let t = top_k(&[0.5, 0.5, 0.5], 3, 2);
        assert_eq!(t[0].0, 0);
        assert_eq!(t[1].0, 1);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    /// Independent model of [`dot`]'s lane semantics, written as the
    /// contract reads — element `i` into lane `i % 8`, fixed pairwise
    /// tree — with none of the chunking machinery. Pins the reduction
    /// order as an explicit spec, not an implementation accident.
    fn dot_lane_model(a: &[f32], b: &[f32]) -> f32 {
        let mut lanes = [0.0f32; DOT_LANES];
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            lanes[i % DOT_LANES] += x * y;
        }
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    fn random_vec(rng: &mut crate::data::Rng, n: usize) -> Vec<f32> {
        // Spread across magnitudes so reduction-order differences are
        // visible at the ulp level if they exist.
        (0..n)
            .map(|_| (rng.below(2001) as f32 - 1000.0) * 1.7e-3)
            .collect()
    }

    #[test]
    fn dot_matches_lane_model_all_lengths() {
        // Property: for every length 0..=513 (odd remainders, unaligned
        // tails, the exact-multiple boundaries) the production dot is
        // bit-identical to the independently written lane model.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0xD07));
        for n in 0..=513usize {
            let a = random_vec(&mut rng, n);
            let b = random_vec(&mut rng, n);
            let got = dot(&a, &b);
            let want = dot_lane_model(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {n}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_is_close_to_sequential_scalar() {
        // The lane reduction is NOT bit-identical to the retired left
        // fold — only numerically equivalent. Pin the tolerance so an
        // accidental fma or reassociation regression shows up.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0xD08));
        for n in [1usize, 7, 8, 64, 257, 512] {
            let a = random_vec(&mut rng, n);
            let b = random_vec(&mut rng, n);
            let simd = dot(&a, &b);
            let scalar = dot_scalar(&a, &b);
            let scale = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f32>();
            assert!(
                (simd - scalar).abs() <= 1e-5 * scale.max(1.0),
                "len {n}: {simd} vs {scalar}"
            );
        }
    }

    #[test]
    fn axpy_bit_identical_to_scalar_loop() {
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0xA49));
        for n in 0..=130usize {
            let x = random_vec(&mut rng, n);
            let alpha = (rng.below(100) as f32 - 50.0) * 0.03;
            let base = random_vec(&mut rng, n);
            let mut fast = base.clone();
            axpy(alpha, &x, &mut fast);
            let mut slow = base;
            for (o, xv) in slow.iter_mut().zip(&x) {
                *o += alpha * xv;
            }
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "len {n} elem {i}");
            }
        }
    }

    #[test]
    fn top_k_small_bit_identical_to_select() {
        // Property: for every k the dispatch can route to the inline
        // path (1..=16), the stack-buffer scan returns exactly what the
        // retired Vec selection returns — indices, scores, tie order —
        // across random lengths with heavy score collisions.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0x70C));
        for case in 0..400 {
            let n = rng.below(80) + 1;
            let k = rng.below(TOP_K_INLINE) + 1;
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(8) as f32) * 0.125).collect();
            let got = top_k_small(&scores, k.min(n));
            let want = top_k_select(&scores, n, k);
            assert_eq!(got, want, "case {case}: n={n} k={k} {scores:?}");
        }
    }

    #[test]
    fn top_k_dispatch_consistent_across_k_boundary() {
        // The k=16 → k=17 dispatch switch must be invisible: on input
        // where both agree on the first 16, the prefix is shared.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0x70D));
        let scores: Vec<f32> = (0..64).map(|_| rng.below(1000) as f32).collect();
        let small = top_k(&scores, 64, 16);
        let large = top_k(&scores, 64, 17);
        assert_eq!(&large[..16], &small[..]);
    }

    /// Reference ranking: stable sort by (score desc, candidate position
    /// asc) — the total order `top_k_hits` must realize.
    fn reference_top_k(all: &[(u32, f32)], k: usize) -> Vec<(u32, f32)> {
        let mut idx: Vec<usize> = (0..all.len()).collect();
        idx.sort_by(|&a, &b| {
            all[b]
                .1
                .partial_cmp(&all[a].1)
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.into_iter().take(k).map(|i| all[i]).collect()
    }

    /// Random candidate lists with heavy score collisions (scores
    /// quantized to 8 levels so ties actually occur).
    fn random_hits(rng: &mut crate::data::Rng, n: usize) -> Vec<(u32, f32)> {
        (0..n)
            .map(|i| (i as u32, (rng.below(8) as f32) * 0.125))
            .collect()
    }

    #[test]
    fn top_k_hits_realizes_a_total_order() {
        // Property: the selection is exactly the stable
        // (score desc, position asc) order — ties are never left to
        // accident, which is what lets the unbatched, batched and
        // sharded merge paths agree bit for bit.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0x70B));
        for case in 0..300 {
            let n = rng.range(1, 40);
            let k = rng.below(12) + 1;
            let all = random_hits(&mut rng, n);
            let got = top_k_hits(all.clone(), k);
            let want = reference_top_k(&all, k);
            assert_eq!(got, want, "case {case}: {all:?} k={k}");
        }
    }

    #[test]
    fn top_k_hits_merge_is_associative() {
        // Property: reducing per-group candidate lists to their local
        // top-k, concatenating the reduced groups in group order, and
        // reducing again gives exactly the direct top-k of the full
        // concatenation. This is the algebra the sharded (and batched)
        // merge rests on: each cluster/shard may pre-reduce its
        // candidates without changing the final ranking or its ties.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(0xA550C));
        for case in 0..300 {
            let groups: Vec<Vec<(u32, f32)>> = (0..rng.range(1, 5))
                .map(|_| {
                    let n = rng.below(16);
                    random_hits(&mut rng, n)
                })
                .collect();
            // Re-tag ids so candidate positions are globally unique and
            // group order is visible in the ids.
            let mut next = 0u32;
            let groups: Vec<Vec<(u32, f32)>> = groups
                .into_iter()
                .map(|g| {
                    g.into_iter()
                        .map(|(_, s)| {
                            next += 1;
                            (next, s)
                        })
                        .collect()
                })
                .collect();
            let k = rng.below(8) + 1;
            let direct: Vec<(u32, f32)> =
                top_k_hits(groups.iter().flatten().copied().collect(), k);
            let staged: Vec<(u32, f32)> = top_k_hits(
                groups
                    .iter()
                    .flat_map(|g| top_k_hits(g.clone(), k))
                    .collect(),
                k,
            );
            assert_eq!(direct, staged, "case {case}: {groups:?} k={k}");
        }
    }
}
