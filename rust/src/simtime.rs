//! Virtual time: deterministic latency accounting for the device model.
//!
//! EdgeRAG's figures are about *device-scale* latencies (Jetson Orin Nano +
//! SD card), which this testbed cannot produce natively. Instead, every
//! component charges its modeled cost to a [`LatencyLedger`]; the retrieval
//! pipeline sums per-component charges into a deterministic, reproducible
//! latency breakdown. Real PJRT compute provides the *numerics* (which
//! embeddings, which scores, what recall) while the ledger provides the
//! *timing* — see DESIGN.md §7 ("virtual clock, real numerics").

use std::fmt;
use std::ops::{Add, AddAssign};
use std::time::Duration;

/// A span of modeled device time, in nanoseconds.
///
/// Thin wrapper over `u64` so device-model code cannot accidentally mix
/// wall-clock and modeled durations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct SimDuration(pub u64);

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_nanos(ns: u64) -> Self {
        SimDuration(ns)
    }
    pub fn from_micros(us: u64) -> Self {
        SimDuration(us * 1_000)
    }
    pub fn from_millis(ms: u64) -> Self {
        SimDuration(ms * 1_000_000)
    }
    /// From fractional seconds (rates are naturally expressed in units/s).
    pub fn from_secs_f64(s: f64) -> Self {
        SimDuration((s.max(0.0) * 1e9).round() as u64)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }
    pub fn as_micros(self) -> u64 {
        self.0 / 1_000
    }
    pub fn as_millis(self) -> u64 {
        self.0 / 1_000_000
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn saturating_sub(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }

    pub fn to_std(self) -> Duration {
        Duration::from_nanos(self.0)
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0 + rhs.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ms = self.as_millis_f64();
        if ms >= 1000.0 {
            write!(f, "{:.2}s", ms / 1000.0)
        } else if ms >= 1.0 {
            write!(f, "{ms:.1}ms")
        } else {
            write!(f, "{}µs", self.as_micros())
        }
    }
}

/// Where modeled time was spent during one retrieval — the categories of
/// the paper's Figure 3 / Figure 6 breakdowns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Component {
    /// Level-1 centroid probe (vector similarity vs centroids).
    CentroidProbe,
    /// Level-2 in-cluster similarity search.
    ClusterSearch,
    /// Online embedding generation (the paper's step 2).
    EmbedGen,
    /// Loading precomputed cluster embeddings from flash (step 3).
    StorageLoad,
    /// Embedding-cache hit service (step 4).
    CacheHit,
    /// Memory-thrash page-in penalties (baseline configs).
    Thrash,
    /// Query embedding generation.
    QueryEmbed,
    /// Fetching the matched data chunks' text.
    ChunkFetch,
    /// LLM prefill (first-token latency).
    Prefill,
    /// LLM weight reload after eviction under memory pressure.
    ModelReload,
}

impl Component {
    pub const ALL: [Component; 10] = [
        Component::CentroidProbe,
        Component::ClusterSearch,
        Component::EmbedGen,
        Component::StorageLoad,
        Component::CacheHit,
        Component::Thrash,
        Component::QueryEmbed,
        Component::ChunkFetch,
        Component::Prefill,
        Component::ModelReload,
    ];

    /// Dense index of this component: its discriminant, which by
    /// declaration order equals its position in [`Component::ALL`] (a
    /// unit test pins the mapping). Metrics arrays index by this instead
    /// of scanning `ALL`.
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Component::CentroidProbe => "centroid_probe",
            Component::ClusterSearch => "cluster_search",
            Component::EmbedGen => "embed_gen",
            Component::StorageLoad => "storage_load",
            Component::CacheHit => "cache_hit",
            Component::Thrash => "thrash",
            Component::QueryEmbed => "query_embed",
            Component::ChunkFetch => "chunk_fetch",
            Component::Prefill => "prefill",
            Component::ModelReload => "model_reload",
        }
    }
}

/// Per-request accumulator of modeled time, split by component.
#[derive(Debug, Clone, Default)]
pub struct LatencyLedger {
    charges: Vec<(Component, SimDuration)>,
}

impl LatencyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn charge(&mut self, component: Component, d: SimDuration) {
        if d > SimDuration::ZERO {
            self.charges.push((component, d));
        }
    }

    /// Total modeled time across all components.
    pub fn total(&self) -> SimDuration {
        self.charges
            .iter()
            .fold(SimDuration::ZERO, |acc, (_, d)| acc + *d)
    }

    /// Time attributed to one component.
    pub fn component(&self, c: Component) -> SimDuration {
        self.charges
            .iter()
            .filter(|(cc, _)| *cc == c)
            .fold(SimDuration::ZERO, |acc, (_, d)| acc + *d)
    }

    /// Retrieval-only portion (everything except prefill/model-reload).
    pub fn retrieval(&self) -> SimDuration {
        self.total()
            .saturating_sub(self.component(Component::Prefill))
            .saturating_sub(self.component(Component::ModelReload))
    }

    pub fn merge(&mut self, other: &LatencyLedger) {
        self.charges.extend_from_slice(&other.charges);
    }

    pub fn is_empty(&self) -> bool {
        self.charges.is_empty()
    }
}

const ALL_LEN: usize = Component::ALL.len();

/// Compact fixed breakdown derived from a ledger; what metrics store.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub by_component: [u64; ALL_LEN], // nanoseconds, indexed by ALL order
}

impl Breakdown {
    pub fn from_ledger(l: &LatencyLedger) -> Self {
        let mut b = Breakdown::default();
        for (i, c) in Component::ALL.iter().enumerate() {
            b.by_component[i] = l.component(*c).as_nanos();
        }
        b
    }

    pub fn get(&self, c: Component) -> SimDuration {
        SimDuration(self.by_component[c.index()])
    }

    pub fn total(&self) -> SimDuration {
        SimDuration(self.by_component.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_conversions() {
        assert_eq!(SimDuration::from_millis(3).as_micros(), 3_000);
        assert_eq!(SimDuration::from_secs_f64(0.5).as_millis(), 500);
        assert_eq!(SimDuration::from_micros(1500).as_millis(), 1);
        assert_eq!(SimDuration::from_secs_f64(-1.0), SimDuration::ZERO);
    }

    #[test]
    fn duration_display() {
        assert_eq!(SimDuration::from_millis(2500).to_string(), "2.50s");
        assert_eq!(SimDuration::from_millis(12).to_string(), "12.0ms");
        assert_eq!(SimDuration::from_micros(250).to_string(), "250µs");
    }

    #[test]
    fn ledger_totals_and_components() {
        let mut l = LatencyLedger::new();
        l.charge(Component::EmbedGen, SimDuration::from_millis(100));
        l.charge(Component::EmbedGen, SimDuration::from_millis(50));
        l.charge(Component::Prefill, SimDuration::from_millis(200));
        assert_eq!(l.total(), SimDuration::from_millis(350));
        assert_eq!(l.component(Component::EmbedGen), SimDuration::from_millis(150));
        assert_eq!(l.retrieval(), SimDuration::from_millis(150));
    }

    #[test]
    fn zero_charges_ignored() {
        let mut l = LatencyLedger::new();
        l.charge(Component::Thrash, SimDuration::ZERO);
        assert!(l.is_empty());
    }

    #[test]
    fn component_index_matches_all_order() {
        // `Component::index()` (the discriminant) must agree with the
        // position in `ALL` — everything that stores per-component
        // arrays (Breakdown, Metrics) indexes by it directly.
        for (i, c) in Component::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "{}", c.name());
        }
        assert_eq!(ALL_LEN, Component::ALL.len());
    }

    #[test]
    fn breakdown_roundtrip() {
        let mut l = LatencyLedger::new();
        l.charge(Component::CentroidProbe, SimDuration::from_micros(42));
        l.charge(Component::StorageLoad, SimDuration::from_millis(7));
        let b = Breakdown::from_ledger(&l);
        assert_eq!(b.get(Component::CentroidProbe).as_micros(), 42);
        assert_eq!(b.get(Component::StorageLoad).as_millis(), 7);
        assert_eq!(b.total(), l.total());
    }

    #[test]
    fn ledger_merge() {
        let mut a = LatencyLedger::new();
        a.charge(Component::EmbedGen, SimDuration::from_millis(1));
        let mut b = LatencyLedger::new();
        b.charge(Component::CacheHit, SimDuration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.total(), SimDuration::from_millis(3));
    }
}
