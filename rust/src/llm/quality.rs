//! Generation-quality proxy — the substitute for the paper's GPT-4o judge
//! (Fig. 11; DESIGN.md §3).
//!
//! The paper uses an LLM judge only to demonstrate that IVF-class
//! retrieval (lower precision, normalized recall) still yields generation
//! quality within ~5% of the flat baseline — i.e., quality is a monotone,
//! saturating function of whether the *relevant* context made it into the
//! prompt. We model exactly that: a deterministic 0–100 score combining
//! (a) whether any ground-truth-relevant chunk was retrieved, and (b) the
//! lexical overlap between the best retrieved chunk and the gold chunk —
//! saturating, so extra irrelevant chunks (precision loss) barely move it,
//! mirroring the judge's behaviour the paper reports ("the generation
//! model is capable of filtering out irrelevant information").

use std::collections::HashSet;

use crate::data::Corpus;
use crate::embedding::tokenizer;

/// Token-set overlap (Jaccard) between two texts under the serving
/// tokenizer.
fn jaccard(a: &str, b: &str) -> f64 {
    let sa: HashSet<i32> = tokenizer::token_ids(a).into_iter().collect();
    let sb: HashSet<i32> = tokenizer::token_ids(b).into_iter().collect();
    if sa.is_empty() || sb.is_empty() {
        return 0.0;
    }
    let inter = sa.intersection(&sb).count() as f64;
    let union = sa.union(&sb).count() as f64;
    inter / union
}

/// Score one answer's grounding: retrieved chunk ids vs. the query's
/// ground truth. Returns 0–100.
pub fn generation_score(
    corpus: &Corpus,
    retrieved: &[u32],
    relevant: &[u32],
    target_chunk: u32,
) -> f64 {
    if retrieved.is_empty() {
        return 0.0;
    }
    let relevant_set: HashSet<u32> = relevant.iter().copied().collect();
    let hit = retrieved.iter().any(|id| relevant_set.contains(id));

    // Best lexical grounding among retrieved chunks vs. the gold chunk.
    let gold = &corpus.chunks[target_chunk as usize].text;
    let best_overlap = retrieved
        .iter()
        .map(|&id| jaccard(&corpus.chunks[id as usize].text, gold))
        .fold(0.0f64, f64::max);

    // Saturating combination: a direct hit dominates; partial overlap
    // (near-duplicates, same-topic chunks) recovers most of the score —
    // the "LLM filters irrelevant context" effect.
    let base = if hit { 70.0 } else { 0.0 };
    base + 30.0 * best_overlap
}

/// Mean generation score over a full workload result set.
pub fn mean_generation_score(
    corpus: &Corpus,
    results: &[(Vec<u32>, Vec<u32>, u32)], // (retrieved, relevant, target)
) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results
        .iter()
        .map(|(ret, rel, t)| generation_score(corpus, ret, rel, *t))
        .sum::<f64>()
        / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;

    fn corpus() -> Corpus {
        Corpus::generate(&DatasetProfile::tiny())
    }

    #[test]
    fn perfect_retrieval_scores_100() {
        let c = corpus();
        let target = 10u32;
        let s = generation_score(&c, &[target], &[target], target);
        assert!((s - 100.0).abs() < 1e-9, "score {s}");
    }

    #[test]
    fn empty_retrieval_scores_0() {
        let c = corpus();
        assert_eq!(generation_score(&c, &[], &[1], 1), 0.0);
    }

    #[test]
    fn irrelevant_retrieval_scores_low() {
        let c = corpus();
        // pick chunks from a different topic than the target
        let target = 0u32;
        let far: Vec<u32> = c
            .chunks
            .iter()
            .filter(|ch| ch.topic != c.chunks[0].topic)
            .take(5)
            .map(|ch| ch.id)
            .collect();
        let s = generation_score(&c, &far, &[target], target);
        assert!(s < 40.0, "score {s}");
    }

    #[test]
    fn extra_irrelevant_chunks_do_not_hurt() {
        // The paper's Fig. 11 point: precision loss ≠ quality loss.
        let c = corpus();
        let target = 20u32;
        let clean = generation_score(&c, &[target], &[target], target);
        let noisy = generation_score(&c, &[5, 300, target, 400, 17], &[target], target);
        assert!((clean - noisy).abs() < 1e-9);
    }

    #[test]
    fn near_duplicate_recovers_most_of_the_score() {
        let c = corpus();
        let dup = c.chunks.iter().find(|ch| ch.group != ch.id).unwrap();
        let orig = dup.group;
        // Retrieved the duplicate instead of the exact target chunk.
        let s = generation_score(&c, &[dup.id], &[orig, dup.id], orig);
        assert!(s > 85.0, "near-duplicate score {s}");
    }

    #[test]
    fn mean_over_workload() {
        let c = corpus();
        let results = vec![
            (vec![1u32], vec![1u32], 1u32),
            (vec![], vec![2u32], 2u32),
        ];
        let m = mean_generation_score(&c, &results);
        assert!((m - 50.0).abs() < 1.0, "mean {m}");
    }
}
