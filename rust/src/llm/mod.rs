//! LLM-side substrate: prompt assembly, prefill (the "first token" half of
//! TTFT), and the generation-quality proxy that substitutes for the
//! paper's GPT-4o judge (DESIGN.md §3).

pub mod prefill;
pub mod quality;

pub use prefill::{Llm, PrefillOutcome};
pub use quality::generation_score;
