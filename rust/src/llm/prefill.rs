//! LLM prefill: prompt assembly + the first-token half of TTFT.
//!
//! TTFT = retrieval + prefill (paper §6.3.4; decode is excluded there too).
//! Prefill cost is linear in prompt tokens at the device's prefill rate,
//! plus a model-reload penalty when memory pressure evicted weight pages
//! (the Fig. 3 "first token latency" blow-up for thrashing configs).
//!
//! A real compiled decoder graph (`prefill_1`) can be executed per request
//! (`real_prefill`), proving the full three-layer path; the figure-scale
//! benches keep it off since its *cost* is what the device model charges.

use anyhow::Result;

use crate::config::DeviceProfile;
use crate::embedding::tokenizer;
use crate::index::SharedMemory;
use crate::runtime::{ComputeHandle, Tensor};
use crate::simtime::{Component, LatencyLedger};
use crate::storage::Region;

/// Result of the prefill stage.
#[derive(Debug, Clone)]
pub struct PrefillOutcome {
    pub prompt_tokens: usize,
    /// Top predicted first-token id (only when `real_prefill` is on).
    pub first_token: Option<i32>,
    /// Bytes of LLM weights that had to be reloaded from storage.
    pub reloaded_bytes: u64,
}

/// The serving-side LLM wrapper.
pub struct Llm {
    device: DeviceProfile,
    memory: SharedMemory,
    compute: Option<ComputeHandle>,
    max_prompt_tokens: usize,
}

impl Llm {
    pub fn new(
        device: DeviceProfile,
        memory: SharedMemory,
        compute: Option<ComputeHandle>,
        max_prompt_tokens: usize,
    ) -> Self {
        Llm {
            device,
            memory,
            compute,
            max_prompt_tokens,
        }
    }

    /// Assemble the generation prompt: query + retrieved chunks, truncated
    /// to the prompt budget (token counting via the serving tokenizer).
    pub fn build_prompt(&self, query: &str, chunks: &[&str]) -> String {
        let mut prompt = String::with_capacity(256);
        prompt.push_str("question: ");
        prompt.push_str(query);
        prompt.push_str(" context:");
        let mut tokens = tokenizer::count_tokens(&prompt);
        for chunk in chunks {
            let t = tokenizer::count_tokens(chunk);
            if tokens + t > self.max_prompt_tokens {
                break;
            }
            prompt.push(' ');
            prompt.push_str(chunk);
            tokens += t;
        }
        prompt
    }

    /// Run prefill: touch model weights (charging reloads under memory
    /// pressure), charge the prefill rate, optionally execute the real
    /// compiled decoder graph.
    pub fn prefill(
        &self,
        prompt: &str,
        ledger: &mut LatencyLedger,
        real_prefill: bool,
    ) -> Result<PrefillOutcome> {
        // Weight residency: thrashing retrieval configs evict LLM pages.
        let reloaded = {
            let mut mem = self.memory.lock().unwrap();
            mem.touch_paged(Region::LlmPage, self.device.llm_weight_bytes)
        };
        if reloaded > 0 {
            ledger.charge(
                Component::ModelReload,
                self.device.storage_read_cost(reloaded, true),
            );
        }

        let prompt_tokens = tokenizer::count_tokens(prompt).max(1);
        ledger.charge(
            Component::Prefill,
            self.device.prefill_cost(prompt_tokens as u64),
        );

        let first_token = if real_prefill {
            let compute = self
                .compute
                .as_ref()
                .expect("real_prefill requires a compute handle");
            let seq = compute.manifest().prefill_seq;
            let mut ids = vec![0i32; seq];
            ids[0] = tokenizer::CLS_ID;
            for (i, tid) in tokenizer::token_ids(prompt)
                .into_iter()
                .take(seq - 1)
                .enumerate()
            {
                ids[i + 1] = tid;
            }
            let out = compute.run("prefill_1", vec![Tensor::I32(ids, vec![1, seq])])?;
            let logits = &out[0];
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32);
            argmax
        } else {
            None
        };

        Ok(PrefillOutcome {
            prompt_tokens,
            first_token,
            reloaded_bytes: reloaded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::shared_memory;

    fn llm(mem_bytes: u64) -> Llm {
        Llm::new(
            DeviceProfile::jetson_orin_nano(),
            shared_memory(mem_bytes),
            None,
            64,
        )
    }

    #[test]
    fn prompt_includes_query_and_chunks() {
        let l = llm(1 << 30);
        let p = l.build_prompt("what is x", &["alpha beta", "gamma delta"]);
        assert!(p.contains("what is x"));
        assert!(p.contains("alpha beta"));
        assert!(p.contains("gamma delta"));
    }

    #[test]
    fn prompt_truncates_to_budget() {
        let l = llm(1 << 30);
        let long: String = (0..200).map(|i| format!("w{i} ")).collect();
        let p = l.build_prompt("q", &[&long, "must not appear"]);
        assert!(tokenizer::count_tokens(&p) <= 64);
        assert!(!p.contains("must not appear"));
    }

    #[test]
    fn prefill_charges_linear_cost() {
        let l = llm(1 << 30);
        let mut la = LatencyLedger::new();
        let mut lb = LatencyLedger::new();
        let short: String = (0..50).map(|i| format!("w{i} ")).collect();
        let long: String = (0..500).map(|i| format!("w{i} ")).collect();
        l.prefill(&short, &mut la, false).unwrap();
        // warm: weights already resident; only prefill differs
        l.prefill(&long, &mut lb, false).unwrap();
        let a = la.component(Component::Prefill);
        let b = lb.component(Component::Prefill);
        assert!(b.as_nanos() > 9 * a.as_nanos());
    }

    #[test]
    fn cold_start_pays_model_reload_once() {
        let l = llm(1 << 30);
        let mut first = LatencyLedger::new();
        let mut second = LatencyLedger::new();
        l.prefill("hello world", &mut first, false).unwrap();
        l.prefill("hello again", &mut second, false).unwrap();
        assert!(first.component(Component::ModelReload).as_millis() > 0);
        assert_eq!(second.component(Component::ModelReload).as_nanos(), 0);
    }

    #[test]
    fn eviction_pressure_forces_reload() {
        let device = DeviceProfile::jetson_orin_nano();
        let mem = shared_memory(device.llm_weight_bytes + (2 << 20));
        let l = Llm::new(device.clone(), mem.clone(), None, 2048);
        let mut ledger = LatencyLedger::new();
        l.prefill("warm up", &mut ledger, false).unwrap();
        // Index activity streams enough clusters to evict model pages.
        {
            let mut m = mem.lock().unwrap();
            for c in 0..64u32 {
                m.touch(Region::Cluster(c), 1 << 20);
            }
        }
        let mut after = LatencyLedger::new();
        let out = l.prefill("query again", &mut after, false).unwrap();
        assert!(out.reloaded_bytes > 0);
        assert!(after.component(Component::ModelReload).as_millis() > 0);
    }
}
