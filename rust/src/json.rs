//! Minimal JSON substrate (parser + writer).
//!
//! The crate cache in this environment has no `serde`/`serde_json`, so the
//! manifest loader, config system, server protocol and experiment reports
//! use this self-contained implementation. Supports the full JSON grammar
//! minus exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    // ---------- accessors ----------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// `obj.get(key)` that errors with context instead of Option.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    // ---------- builders ----------
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn array<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Array(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::String(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Number(n)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Number(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Number(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Number(v as f64)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Number(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::String(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::String(v)
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write(self, f, None, 0)
    }
}

impl Value {
    /// Pretty-printed with 1-space indent (matches aot.py's output style).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        use fmt::Write;
        let mut fw = FmtAdapter(&mut s);
        write(self, &mut fw, Some(1), 0).unwrap();
        let _ = fw.write_str("");
        s
    }
}

struct FmtAdapter<'a>(&'a mut String);
impl fmt::Write for FmtAdapter<'_> {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        self.0.push_str(s);
        Ok(())
    }
}

fn write<W: fmt::Write>(
    v: &Value,
    f: &mut W,
    indent: Option<usize>,
    depth: usize,
) -> fmt::Result {
    let nl = |f: &mut W, d: usize| -> fmt::Result {
        if let Some(i) = indent {
            f.write_char('\n')?;
            for _ in 0..i * d {
                f.write_char(' ')?;
            }
        }
        Ok(())
    };
    match v {
        Value::Null => f.write_str("null"),
        Value::Bool(true) => f.write_str("true"),
        Value::Bool(false) => f.write_str("false"),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Value::String(s) => write_string(s, f),
        Value::Array(items) => {
            f.write_char('[')?;
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    f.write_char(',')?;
                    if indent.is_none() {
                        f.write_char(' ')?;
                    }
                }
                nl(f, depth + 1)?;
                write(item, f, indent, depth + 1)?;
            }
            if !items.is_empty() {
                nl(f, depth)?;
            }
            f.write_char(']')
        }
        Value::Object(map) => {
            f.write_char('{')?;
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    f.write_char(',')?;
                    if indent.is_none() {
                        f.write_char(' ')?;
                    }
                }
                nl(f, depth + 1)?;
                write_string(k, f)?;
                f.write_str(": ")?;
                write(val, f, indent, depth + 1)?;
            }
            if !map.is_empty() {
                nl(f, depth)?;
            }
            f.write_char('}')
        }
    }
}

fn write_string<W: fmt::Write>(s: &str, f: &mut W) -> fmt::Result {
    f.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_char('"')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr": [1, 2.5, true, null], "s": "x\"y", "o": {"k": -7}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn parses_real_manifest() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        let text = std::fs::read_to_string(path).expect("make artifacts first");
        let v = parse(&text).unwrap();
        assert_eq!(v.get("dim").unwrap().as_usize(), Some(256));
        assert!(v.get("artifacts").unwrap().as_array().unwrap().len() >= 10);
    }

    #[test]
    fn number_integer_formatting() {
        assert_eq!(Value::Number(3.0).to_string(), "3");
        assert_eq!(Value::Number(3.25).to_string(), "3.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap().to_string(), "[]");
        assert_eq!(parse("{}").unwrap().to_string(), "{}");
    }
}
