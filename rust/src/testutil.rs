//! Shared fixtures for tests, benches and examples: one compute executor
//! per process (PJRT client construction is expensive; all PJRT state
//! lives on the executor thread — see `runtime::service`).

use std::path::PathBuf;
use std::sync::OnceLock;

use crate::runtime::ComputeHandle;

/// Repository-root artifacts directory (works from tests/benches/examples).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The process-wide shared compute handle. Uses real PJRT artifacts when
/// `make artifacts` has been run, and the deterministic reference compute
/// backend otherwise (see `runtime::reference`).
pub fn shared_compute() -> ComputeHandle {
    static RT: OnceLock<ComputeHandle> = OnceLock::new();
    RT.get_or_init(|| {
        ComputeHandle::start(&artifacts_dir()).expect("starting compute service")
    })
    .clone()
}
