//! Shared fixtures for tests, benches and examples: one compute executor
//! per process (PJRT client construction is expensive; all PJRT state
//! lives on the executor thread — see `runtime::service`).

use std::path::PathBuf;
use std::sync::OnceLock;

use crate::runtime::ComputeHandle;

/// Repository-root artifacts directory (works from tests/benches/examples).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The process-wide shared compute handle. Panics if `make artifacts` has
/// not been run.
pub fn shared_compute() -> ComputeHandle {
    static RT: OnceLock<ComputeHandle> = OnceLock::new();
    RT.get_or_init(|| {
        ComputeHandle::start(&artifacts_dir())
            .expect("starting compute executor — run `make artifacts` first")
    })
    .clone()
}
