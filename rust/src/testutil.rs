//! Shared fixtures for tests, benches and examples: one compute executor
//! per process (PJRT client construction is expensive; all PJRT state
//! lives on the executor thread — see `runtime::service`).

use std::path::PathBuf;
use std::sync::OnceLock;

use crate::runtime::ComputeHandle;

/// Repository-root artifacts directory (works from tests/benches/examples).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The process-wide shared compute handle. Uses real PJRT artifacts when
/// `make artifacts` has been run, and the deterministic reference compute
/// backend otherwise (see `runtime::reference`).
pub fn shared_compute() -> ComputeHandle {
    static RT: OnceLock<ComputeHandle> = OnceLock::new();
    RT.get_or_init(|| {
        ComputeHandle::start(&artifacts_dir()).expect("starting compute service")
    })
    .clone()
}

/// The seed every randomized test derives its `Rng` from: `default_seed`
/// unless `EDGERAG_TEST_SEED` overrides it. The effective seed is printed
/// to stderr so a failing run's captured output always names the seed to
/// reproduce it with (`EDGERAG_TEST_SEED=<n> cargo test …`) — CI's
/// unfixed-seed churn job relies on this to make flakes replayable.
pub fn test_seed(default_seed: u64) -> u64 {
    let seed = match std::env::var("EDGERAG_TEST_SEED") {
        Ok(v) => v
            .trim()
            .parse()
            .expect("EDGERAG_TEST_SEED must be an unsigned integer"),
        Err(_) => default_seed,
    };
    eprintln!("EDGERAG_TEST_SEED={seed} (set this env var to reproduce)");
    seed
}
