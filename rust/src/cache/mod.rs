//! EdgeRAG's adaptive cost-aware caching layer (paper §4.2): the
//! cost-aware LFU cache (Algorithm 2) gated by the adaptive Minimum
//! Latency Caching Threshold (Algorithm 3).

pub mod cost_lfu;
pub mod threshold;

pub use cost_lfu::{CacheStats, CostAwareCache};
pub use threshold::ThresholdController;
