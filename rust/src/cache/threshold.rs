//! Minimum Latency Caching Threshold controller — paper Algorithm 3.
//!
//! Gates what the cost-aware cache may hold: clusters whose generation
//! latency is below the threshold are not worth caching (they regenerate
//! fast anyway), so the cache's bytes concentrate on expensive clusters.
//!
//! The controller is a simple feedback loop over per-query observations:
//! on a cache miss whose retrieval latency came out *above* the moving
//! average, the threshold increases (pressure: reserve the cache for
//! costlier clusters); on a hit it decreases (slack: we can afford to
//! cache more). The paper's prose and pseudocode disagree on the miss
//! comparison's direction (`movAvgLatency < lastLatency` in Algorithm 3 vs
//! "current retrieval latency is lower than the moving average" in §4.2);
//! we follow the pseudocode, which is the stable direction: misses that
//! hurt latency push the threshold up.

/// Adaptive threshold state.
#[derive(Debug, Clone)]
pub struct ThresholdController {
    threshold_ms: f64,
    mov_avg_ms: f64,
    alpha: f64,
    step_ms: f64,
    /// Upper bound (the dataset SLO): clusters costlier than the SLO are
    /// always worth caching, so the threshold never exceeds it. Also
    /// prevents controller runaway on low-reuse workloads.
    cap_ms: f64,
    observations: u64,
}

impl ThresholdController {
    /// `alpha`: EWMA coefficient for the moving-average latency;
    /// `step_ms`: the `++`/`--` increment of Algorithm 3.
    pub fn new(alpha: f64, step_ms: f64, cap_ms: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        ThresholdController {
            threshold_ms: 0.0, // Algorithm 3: initialize to 0 (cache all)
            mov_avg_ms: 0.0,
            alpha,
            step_ms,
            cap_ms,
            observations: 0,
        }
    }

    pub fn threshold_ms(&self) -> f64 {
        self.threshold_ms
    }

    pub fn moving_avg_ms(&self) -> f64 {
        self.mov_avg_ms
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Would a cluster with this generation latency be cached right now?
    pub fn should_cache(&self, gen_latency_ms: f64) -> bool {
        gen_latency_ms >= self.threshold_ms
    }

    /// Feed one query's outcome (Algorithm 3 body).
    pub fn observe(&mut self, cache_miss: bool, last_latency_ms: f64) {
        if self.observations == 0 {
            self.mov_avg_ms = last_latency_ms; // seed the EWMA
        }
        if cache_miss {
            if self.mov_avg_ms < last_latency_ms {
                self.threshold_ms = (self.threshold_ms + self.step_ms).min(self.cap_ms);
            }
        } else {
            self.threshold_ms = (self.threshold_ms - self.step_ms).max(0.0);
        }
        self.mov_avg_ms =
            (1.0 - self.alpha) * self.mov_avg_ms + self.alpha * last_latency_ms;
        self.observations += 1;
    }

    /// Pin the threshold (used by the Fig. 7 sweep, which evaluates fixed
    /// thresholds instead of the adaptive loop).
    pub fn pin(&mut self, threshold_ms: f64) {
        self.threshold_ms = threshold_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_caching_everything() {
        let t = ThresholdController::new(0.2, 10.0, 1_000.0);
        assert_eq!(t.threshold_ms(), 0.0);
        assert!(t.should_cache(0.001));
    }

    #[test]
    fn slow_misses_raise_threshold() {
        let mut t = ThresholdController::new(0.2, 10.0, 1_000.0);
        t.observe(true, 100.0); // seeds avg at 100; no raise (avg !< last)
        for _ in 0..5 {
            t.observe(true, 500.0); // misses far above average
        }
        assert!(t.threshold_ms() >= 40.0, "threshold {}", t.threshold_ms());
    }

    #[test]
    fn hits_lower_threshold_to_zero_floor() {
        let mut t = ThresholdController::new(0.2, 10.0, 1_000.0);
        t.pin(25.0);
        t.observe(false, 10.0);
        t.observe(false, 10.0);
        assert!((t.threshold_ms() - 5.0).abs() < 1e-9);
        t.observe(false, 10.0);
        assert_eq!(t.threshold_ms(), 0.0, "must clamp at zero");
        t.observe(false, 10.0);
        assert_eq!(t.threshold_ms(), 0.0);
    }

    #[test]
    fn fast_misses_do_not_raise() {
        let mut t = ThresholdController::new(0.2, 10.0, 1_000.0);
        t.observe(true, 1000.0); // seed high
        t.observe(true, 10.0);   // fast miss: avg(1000) < last(10)? no → no raise
        assert_eq!(t.threshold_ms(), 0.0);
    }

    #[test]
    fn ewma_tracks_latency() {
        let mut t = ThresholdController::new(0.5, 1.0, 1_000.0);
        t.observe(false, 100.0);
        assert!((t.moving_avg_ms() - 100.0).abs() < 1e-9);
        t.observe(false, 200.0);
        assert!((t.moving_avg_ms() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn cap_bounds_threshold() {
        let mut t = ThresholdController::new(0.2, 100.0, 250.0);
        t.observe(true, 100.0);
        for _ in 0..50 {
            t.observe(true, 10_000.0);
        }
        assert!(t.threshold_ms() <= 250.0);
    }

    #[test]
    fn converges_under_alternating_load() {
        // Mixed hits/misses with stable latency: threshold must stay
        // bounded (no runaway).
        let mut t = ThresholdController::new(0.2, 10.0, 1_000.0);
        let mut rng = crate::data::Rng::new(3);
        for i in 0..10_000 {
            let miss = i % 3 == 0;
            let lat = 200.0 + 50.0 * rng.normal();
            t.observe(miss, lat.max(1.0));
            assert!(t.threshold_ms() >= 0.0);
            assert!(t.threshold_ms() < 5_000.0, "runaway threshold");
        }
    }

    #[test]
    fn should_cache_respects_threshold() {
        let mut t = ThresholdController::new(0.2, 10.0, 1_000.0);
        t.pin(100.0);
        assert!(!t.should_cache(50.0));
        assert!(t.should_cache(100.0));
        assert!(t.should_cache(500.0));
    }
}
