//! Cost-aware Least-Frequently-Used embedding cache — paper Algorithm 2.
//!
//! Each entry is one cluster's generated embeddings, weighted by its
//! profiled generation latency. Eviction removes the entry minimizing
//! `genLatency × useCounter` (cheap-to-regenerate AND rarely used first);
//! counters decay multiplicatively after every access so the policy tracks
//! shifting query mixes.
//!
//! ## Read path vs mutation path
//!
//! The concurrent serving engine guards this structure with an `RwLock`
//! and splits every lookup in two:
//!
//! * [`CostAwareCache::peek`] — `&self`, safe under a read lock: returns
//!   the cached `Arc` (or `None`) and records hit/miss statistics through
//!   atomics, so many queries can probe the cache simultaneously;
//! * [`CostAwareCache::touch`] / [`CostAwareCache::advance_epoch`] /
//!   [`CostAwareCache::insert`] — `&mut self`, applied at commit time
//!   under the write lock, replaying the counter bumps and decay epochs
//!   the peeks deferred.
//!
//! [`CostAwareCache::access`] remains the classic combined hit path
//! (peek + touch in one call) for single-threaded callers and tests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::vecmath::EmbeddingMatrix;

#[derive(Debug)]
struct Entry {
    /// Shared with callers: hits hand out an `Arc` clone instead of
    /// copying the whole matrix (perf pass §Perf item L3-1).
    emb: Arc<EmbeddingMatrix>,
    /// Profiled generation latency, milliseconds (the cost weight).
    gen_latency_ms: f64,
    /// Use counter as of `epoch` (lazily decayed — §Perf item L3-2).
    counter: f64,
    /// Decay epoch at which `counter` was last materialized.
    epoch: u64,
    bytes: u64,
}

impl Entry {
    /// Counter decayed forward to `now` without mutating.
    fn counter_at(&self, now: u64, decay: f64) -> f64 {
        self.counter * decay.powi((now - self.epoch) as i32)
    }
}

/// Statistics the experiment harness reports (hit rates, Fig. 7).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups that found their cluster cached.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Entries admitted (threshold-gated inserts).
    pub insertions: u64,
    /// Entries evicted (capacity pressure or threshold sweeps).
    pub evictions: u64,
    /// Admissions declined by the Alg. 3 threshold gate.
    pub rejected_below_threshold: u64,
}

impl CacheStats {
    /// hits ÷ (hits + misses); 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Internal atomic counters so the lock-free read path can record
/// hits/misses through `&self`.
#[derive(Debug, Default)]
struct AtomicStats {
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    rejected_below_threshold: AtomicU64,
}

impl AtomicStats {
    fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            rejected_below_threshold: self.rejected_below_threshold.load(Ordering::Relaxed),
        }
    }
}

/// The cost-aware LFU cache over generated cluster embeddings.
///
/// Algorithm 2's trailing "decay every counter after each access" loop is
/// implemented lazily: a global epoch advances per access, and each
/// entry's counter is materialized as `counter × decay^(epoch − touched)`
/// on demand — O(1) per access instead of O(entries), with identical
/// eviction decisions (uniform multiplicative decay preserves relative
/// weights between touches).
#[derive(Debug)]
pub struct CostAwareCache {
    capacity_bytes: u64,
    used_bytes: u64,
    decay: f64,
    epoch: u64,
    entries: HashMap<u32, Entry>,
    stats: AtomicStats,
}

impl CostAwareCache {
    /// An empty cache with a byte capacity and the Alg. 2 decay factor
    /// (`decay` in `[0, 1]`; 1 disables decay).
    pub fn new(capacity_bytes: u64, decay: f64) -> Self {
        assert!((0.0..=1.0).contains(&decay));
        CostAwareCache {
            capacity_bytes,
            used_bytes: 0,
            decay,
            epoch: 0,
            entries: HashMap::new(),
            stats: AtomicStats::default(),
        }
    }

    /// Configured byte capacity.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Bytes currently held (always ≤ capacity).
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Number of cached cluster entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Snapshot of the hit/miss/insertion/eviction counters.
    pub fn stats(&self) -> CacheStats {
        self.stats.snapshot()
    }

    /// True when `cluster`'s embeddings are cached.
    pub fn contains(&self, cluster: u32) -> bool {
        self.entries.contains_key(&cluster)
    }

    /// Statistics-neutral entry lookup: the cached embeddings plus their
    /// profiled generation latency, with **no** hit/miss accounting and
    /// no LFU mutation. This is the cross-shard migration export path
    /// (and the rebalance planner's cached-mass accounting) — a cluster
    /// being moved between shards is not a cache access and must not
    /// perturb the hit-rate statistics the experiments report.
    pub fn entry(&self, cluster: u32) -> Option<(Arc<EmbeddingMatrix>, f64)> {
        self.entries
            .get(&cluster)
            .map(|e| (e.emb.clone(), e.gen_latency_ms))
    }

    /// Read-path lookup: returns the cached embeddings without mutating
    /// LFU state, counting the hit/miss atomically. The counter bump and
    /// decay-epoch advance are deferred to [`touch`](Self::touch) /
    /// [`advance_epoch`](Self::advance_epoch) at commit time.
    pub fn peek(&self, cluster: u32) -> Option<Arc<EmbeddingMatrix>> {
        match self.entries.get(&cluster) {
            Some(e) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.emb.clone())
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Commit-path half of a hit: bump the entry's (lazily decayed) use
    /// counter and advance the global decay epoch by one access. A no-op
    /// counter-wise if the entry was removed in between (stale touch).
    pub fn touch(&mut self, cluster: u32) {
        let now = self.epoch;
        let decay = self.decay;
        if let Some(e) = self.entries.get_mut(&cluster) {
            e.counter = e.counter_at(now, decay) + 1.0;
            e.epoch = now;
        }
        self.epoch += 1;
    }

    /// Advance the decay epoch by `accesses` cache misses (Algorithm 2's
    /// trailing decay loop also runs on misses).
    pub fn advance_epoch(&mut self, accesses: u64) {
        self.epoch += accesses;
    }

    /// Look up a cluster's embeddings. On hit, bumps the entry's counter;
    /// the global decay epoch advances either way (Algorithm 2's trailing
    /// decay loop, applied lazily). Combined peek + touch for
    /// single-threaded callers.
    pub fn access(&mut self, cluster: u32) -> Option<Arc<EmbeddingMatrix>> {
        let hit = self.peek(cluster);
        if hit.is_some() {
            self.touch(cluster); // advances the epoch too
        } else {
            self.epoch += 1;
        }
        hit
    }

    /// Insert a freshly generated cluster (Algorithm 2 miss path), evicting
    /// minimum `genLatency × counter` entries until it fits. Entries larger
    /// than the whole cache are not cached. Returns evicted cluster ids
    /// (callers release their memory-model regions).
    pub fn insert(
        &mut self,
        cluster: u32,
        emb: Arc<EmbeddingMatrix>,
        gen_latency_ms: f64,
    ) -> Vec<u32> {
        let bytes = emb.bytes();
        let mut evicted = Vec::new();
        if bytes > self.capacity_bytes {
            return evicted; // would displace everything; never worth it
        }
        // Re-inserting an id replaces the old entry (size may differ after
        // cluster updates): release its bytes first.
        self.remove(cluster);
        while self.used_bytes + bytes > self.capacity_bytes {
            // Weighted-LFU victim: min genLatency × (lazily decayed) counter.
            let (now, decay) = (self.epoch, self.decay);
            let victim = self
                .entries
                .iter()
                .min_by(|a, b| {
                    let ka = a.1.gen_latency_ms * a.1.counter_at(now, decay);
                    let kb = b.1.gen_latency_ms * b.1.counter_at(now, decay);
                    ka.partial_cmp(&kb).unwrap()
                })
                .map(|(id, _)| *id);
            match victim {
                Some(v) => {
                    self.remove(v);
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                    evicted.push(v);
                }
                None => break,
            }
        }
        self.used_bytes += bytes;
        self.entries.insert(
            cluster,
            Entry {
                emb,
                gen_latency_ms,
                counter: 1.0,
                epoch: self.epoch,
                bytes,
            },
        );
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        evicted
    }

    /// Count an insertion rejected by the adaptive threshold (Alg. 3 gate).
    pub fn note_rejected(&self) {
        self.stats
            .rejected_below_threshold
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Remove one entry (threshold-driven eviction, cluster removal, a
    /// migration retiring its source copy, or a merge invalidating both
    /// sides — the absorbed rows' cache entry does *not* hand off to the
    /// merge victim: the victim's own entry is stale the moment its
    /// membership grows, so both entries drop and the merged cluster
    /// re-admits through the normal threshold gate on its next miss,
    /// exactly as the unsharded inline path behaves).
    pub fn remove(&mut self, cluster: u32) -> bool {
        if let Some(e) = self.entries.remove(&cluster) {
            self.used_bytes -= e.bytes;
            true
        } else {
            false
        }
    }

    /// Evict every entry whose generation latency is below `threshold_ms`
    /// (Algorithm 3: "evicts and prevents caching of cluster embeddings
    /// whose generation latency falls below the threshold"). Returns the
    /// evicted ids.
    pub fn evict_below(&mut self, threshold_ms: f64) -> Vec<u32> {
        let victims: Vec<u32> = self
            .entries
            .iter()
            .filter(|(_, e)| e.gen_latency_ms < threshold_ms)
            .map(|(id, _)| *id)
            .collect();
        for v in &victims {
            self.remove(*v);
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        }
        victims
    }

    /// (cluster id, genLatency×counter) pairs — introspection for tests
    /// and the metrics endpoint.
    pub fn weights(&self) -> Vec<(u32, f64)> {
        self.entries
            .iter()
            .map(|(id, e)| (*id, e.gen_latency_ms * e.counter_at(self.epoch, self.decay)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(rows: usize) -> Arc<EmbeddingMatrix> {
        let mut m = EmbeddingMatrix::new(4);
        for i in 0..rows {
            m.push(&[i as f32; 4]);
        }
        Arc::new(m)
    }

    fn row_bytes() -> u64 {
        16
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = CostAwareCache::new(1000, 0.9);
        assert!(c.access(1).is_none());
        c.insert(1, emb(2), 50.0);
        assert!(c.access(1).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert_eq!(c.used_bytes(), 2 * row_bytes());
    }

    #[test]
    fn evicts_min_cost_times_counter() {
        // capacity for exactly two 1-row entries
        let mut c = CostAwareCache::new(2 * row_bytes(), 1.0);
        c.insert(1, emb(1), 100.0); // weight 100×1
        c.insert(2, emb(1), 10.0);  // weight 10×1
        // bump 2's counter so weights become 100 vs 10×~2
        c.access(2);
        // inserting 3 must evict the *lower* weight entry — still 2? 10×2=20 < 100
        let evicted = c.insert(3, emb(1), 50.0);
        assert_eq!(evicted, vec![2]);
        assert!(c.contains(1) && c.contains(3));
    }

    #[test]
    fn frequency_protects_cheap_entries() {
        let mut c = CostAwareCache::new(2 * row_bytes(), 1.0);
        c.insert(1, emb(1), 10.0);
        c.insert(2, emb(1), 100.0);
        for _ in 0..20 {
            c.access(1); // weight(1) = 10 × 21 = 210 > 100
        }
        let evicted = c.insert(3, emb(1), 50.0);
        assert_eq!(evicted, vec![2], "frequently-used cheap entry must survive");
    }

    #[test]
    fn counters_decay() {
        let mut c = CostAwareCache::new(1000, 0.5);
        c.insert(1, emb(1), 10.0);
        c.access(1); // counter: 1 → 2, then decay → 1.0
        c.access(9); // miss; decay → 0.5
        c.access(9); // miss; decay → 0.25
        let w = c.weights();
        let w1 = w.iter().find(|(id, _)| *id == 1).unwrap().1;
        assert!((w1 - 10.0 * 0.25).abs() < 1e-9, "weight {w1}");
    }

    #[test]
    fn peek_then_touch_matches_access() {
        // The split read/commit path must reproduce access()'s LFU state
        // when replayed in probe order — both hit-then-miss and
        // miss-then-hit (the decay epoch between them matters).
        for miss_first in [false, true] {
            let mut a = CostAwareCache::new(1000, 0.5);
            let mut b = CostAwareCache::new(1000, 0.5);
            a.insert(1, emb(1), 10.0);
            b.insert(1, emb(1), 10.0);
            // combined path
            if miss_first {
                a.access(9);
                a.access(1);
            } else {
                a.access(1);
                a.access(9);
            }
            // split path: peeks first (read lock), then ordered replay
            if miss_first {
                assert!(b.peek(9).is_none());
                assert!(b.peek(1).is_some());
                b.advance_epoch(1);
                b.touch(1);
            } else {
                assert!(b.peek(1).is_some());
                assert!(b.peek(9).is_none());
                b.touch(1);
                b.advance_epoch(1);
            }
            let wa = a.weights();
            let wb = b.weights();
            assert_eq!(wa.len(), wb.len());
            assert!(
                (wa[0].1 - wb[0].1).abs() < 1e-12,
                "miss_first={miss_first}: {wa:?} vs {wb:?}"
            );
            assert_eq!(a.stats(), b.stats(), "miss_first={miss_first}");
        }
    }

    #[test]
    fn stale_touch_is_noop() {
        let mut c = CostAwareCache::new(1000, 0.9);
        c.insert(1, emb(1), 10.0);
        c.remove(1);
        c.touch(1); // entry gone: counter no-op, epoch still advances
        assert!(!c.contains(1));
    }

    #[test]
    fn oversized_entry_not_cached() {
        let mut c = CostAwareCache::new(3 * row_bytes(), 0.9);
        c.insert(1, emb(1), 10.0);
        let evicted = c.insert(2, emb(10), 99.0);
        assert!(evicted.is_empty());
        assert!(!c.contains(2));
        assert!(c.contains(1), "existing entries must not be displaced");
    }

    #[test]
    fn evict_below_threshold() {
        let mut c = CostAwareCache::new(1000, 0.9);
        c.insert(1, emb(1), 5.0);
        c.insert(2, emb(1), 50.0);
        c.insert(3, emb(1), 500.0);
        let mut v = c.evict_below(60.0);
        v.sort_unstable();
        assert_eq!(v, vec![1, 2]);
        assert!(c.contains(3));
    }

    #[test]
    fn multi_entry_eviction_for_large_insert() {
        let mut c = CostAwareCache::new(4 * row_bytes(), 1.0);
        c.insert(1, emb(1), 1.0);
        c.insert(2, emb(1), 2.0);
        c.insert(3, emb(1), 3.0);
        c.insert(4, emb(1), 4.0);
        // inserting a 3-row entry must evict the three cheapest
        let mut evicted = c.insert(9, emb(3), 100.0);
        evicted.sort_unstable();
        assert_eq!(evicted, vec![1, 2, 3]);
        assert!(c.contains(4) && c.contains(9));
        assert!(c.used_bytes() <= c.capacity_bytes());
    }

    #[test]
    fn capacity_invariant_holds_randomized() {
        // Property-style sweep with the deterministic Rng: the capacity
        // invariant and stats consistency hold under arbitrary workloads.
        let mut rng = crate::data::Rng::new(crate::testutil::test_seed(42));
        let mut c = CostAwareCache::new(64 * row_bytes(), 0.9);
        for _ in 0..2000 {
            let id = rng.below(50) as u32;
            if rng.f64() < 0.5 {
                c.access(id);
            } else {
                let rows = rng.range(1, 8);
                let lat = rng.f64() * 1000.0;
                c.insert(id, emb(rows), lat);
            }
            assert!(c.used_bytes() <= c.capacity_bytes());
            let by_sum: u64 = c.weights().len() as u64;
            assert_eq!(by_sum as usize, c.len());
        }
        let s = c.stats();
        assert!(s.hits > 0 && s.misses > 0 && s.evictions > 0);
    }

    #[test]
    fn concurrent_peeks_count_stats() {
        // peek is &self: many readers may probe simultaneously under a
        // read lock; stats must not lose updates.
        let mut c = CostAwareCache::new(1000, 0.9);
        c.insert(1, emb(1), 10.0);
        let c = std::sync::Arc::new(c);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        assert!(c.peek(1).is_some());
                        assert!(c.peek(2).is_none());
                    }
                });
            }
        });
        let stats = c.stats();
        assert_eq!(stats.hits, 2000);
        assert_eq!(stats.misses, 2000);
    }
}
