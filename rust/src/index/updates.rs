//! Online insertion and removal (paper §5.4), plus the per-shard halves
//! of the cross-shard structural operations (cluster migration and
//! cross-shard merge routing — the online rebalancer,
//! `crate::index::rebalance`, and
//! [`ShardedEdgeIndex::remove_chunk`](crate::index::ShardedEdgeIndex)).
//!
//! Insertion routes a new chunk to the nearest existing centroid and
//! updates that cluster's index; if the updated cluster's generation cost
//! exceeds the SLO-derived limit its embeddings are regenerated and
//! stored. Excessively large clusters split in two (the new cluster joins
//! the first level). Removal deletes the chunk; clusters that become too
//! small merge into their nearest neighbour (a tombstone remains in the
//! centroid table, masked out of probes). Victim selection
//! ([`EdgeIndex::merge_victim`]) is separated from merge execution
//! (`EdgeIndex::merge_into`) so the sharded index can select the
//! **global** nearest neighbour and, when the victim lives on another
//! shard, compose the merge from the migration primitive
//! (migrate-then-merge — see `crate::index::shard`).
//!
//! Migration decomposes into three shard-local operations driven by
//! [`ShardedEdgeIndex::migrate_cluster`](crate::index::ShardedEdgeIndex::migrate_cluster):
//! `EdgeIndex::export_cluster` (read-only snapshot of everything a
//! cluster owns), `EdgeIndex::import_cluster` (append the snapshot as a
//! fresh local cluster on the destination) and
//! `EdgeIndex::retire_cluster` (tombstone the source copy and release
//! its blob/cache/memory resources).
//!
//! Merge execution splits the same way, into a fallible planning half
//! and an infallible mutation half, so the composed cross-shard op can
//! order **every fallible blob operation before any irreversible
//! in-memory mutation** (blob-first failure atomicity): a `MergePlan` is
//! computed read-only (`EdgeIndex::plan_merge`), the blob transition
//! applies under the destination's write lease
//! (`EdgeIndex::apply_merge_blob`) and only then does the infallible
//! `EdgeIndex::apply_merge_members` rewire membership. A failure at
//! any fallible step leaves the index serving its previous, consistent
//! state and the merge retries cleanly.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::index::edge::EdgeIndex;
use crate::simtime::SimDuration;
use crate::storage::WalOp;
use crate::vecmath;
use crate::vecmath::EmbeddingMatrix;

/// A cluster splits when it exceeds this many members (×  the dataset's
/// mean would be adaptive; a fixed generous bound keeps behaviour easy to
/// reason about and matches the paper's "extreme cases" wording).
pub const SPLIT_THRESHOLD: usize = 2048;
/// A cluster merges away when it falls below this many members.
pub const MERGE_THRESHOLD: usize = 2;

/// Everything one cluster owns inside a shard, packaged for cross-shard
/// migration: the centroid, resident metadata, the online-update overlay
/// rows for its dynamic chunks, its precomputed blob (if stored) and its
/// cache entry (if resident). Produced read-only by
/// `EdgeIndex::export_cluster`; consumed by `EdgeIndex::import_cluster`.
#[derive(Debug, Clone)]
pub struct ClusterExport {
    pub(crate) centroid: Vec<f32>,
    pub(crate) chunk_ids: Vec<u32>,
    pub(crate) chars: u64,
    pub(crate) gen_cost: SimDuration,
    /// `(chunk id, text, embedding)` rows of the source's dynamic overlay
    /// belonging to this cluster.
    pub(crate) dynamic: Vec<(u32, String, Vec<f32>)>,
    /// The precomputed blob contents, when selective storage holds one.
    pub(crate) blob: Option<EmbeddingMatrix>,
    /// The cache entry (`Arc`'d embeddings + profiled gen latency), when
    /// resident. The destination re-admits it with a fresh use counter.
    pub(crate) cache: Option<(Arc<EmbeddingMatrix>, f64)>,
}

impl EdgeIndex {
    /// Insert a new chunk (§5.4). `id` must be fresh; `emb` is the chunk's
    /// embedding (computed by the caller's embedder — same model as
    /// indexing). Returns the cluster it joined (which may be a fresh
    /// cluster if the target split).
    pub fn insert_chunk(&mut self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        if self.chunk_cluster.contains_key(&id) {
            bail!("chunk id {id} already present");
        }
        // Record-before-mutation: once validation passes, the op hits the
        // WAL before anything irreversible. An append failure aborts here
        // with the index untouched; a crash after the append replays it.
        self.wal_append(&WalOp::Insert {
            id,
            text: text.to_string(),
            emb: emb.to_vec(),
        })?;
        // Invalidate in-flight cache intents: admissions gathered before
        // this update may carry stale embeddings. The probe snapshot is
        // dropped too (no reader can rebuild it mid-update: we hold
        // `&mut self` — the engine or shard write lease).
        self.update_gen.fetch_add(1, Ordering::Release);
        self.invalidate_probe_snapshot();
        // Nearest active centroid.
        let target = self
            .probe(emb, 1)?
            .first()
            .map(|&(c, _)| c as u32)
            .ok_or_else(|| anyhow::anyhow!("no active clusters"))?;

        self.dynamic.insert(id, (text.to_string(), emb.to_vec()));
        self.chunk_cluster.insert(id, target);
        {
            let meta = &mut self.clusters.clusters[target as usize];
            meta.chunk_ids.push(id);
            meta.chars += text.len() as u64;
        }
        self.refresh_cluster(target)?;

        if self.clusters.clusters[target as usize].len() > SPLIT_THRESHOLD {
            self.split_cluster(target)?;
        }
        Ok(self.chunk_cluster[&id])
    }

    /// Remove a chunk (§5.4). Returns false if unknown. A cluster that
    /// drains below [`MERGE_THRESHOLD`] merges into its nearest active
    /// neighbour inline — the single-index (oracle) semantics.
    pub fn remove_chunk(&mut self, id: u32) -> Result<bool> {
        let (removed, drained) = self.remove_chunk_deferred(id)?;
        if let Some(cluster) = drained {
            self.merge_cluster(cluster)?;
        }
        Ok(removed)
    }

    /// Remove a chunk **without** the inline merge: when the owning
    /// cluster drains below [`MERGE_THRESHOLD`] its id is returned
    /// instead, so the caller can route the merge itself. This is the
    /// sharded index's entry point: the shard-local nearest neighbour is
    /// not necessarily the *global* nearest, so the sharded wrapper
    /// selects the victim against the spliced probe snapshot and merges
    /// cross-shard when the victim lives elsewhere.
    /// Removal is **blob-first**: the post-removal accounting is computed
    /// read-only, the fallible blob transition runs against that planned
    /// state (storing the post-removal rows via
    /// [`EdgeIndex::gather_without`], or dropping the blob), and only
    /// then does the infallible half mutate membership. A blob fault
    /// therefore aborts the removal with the index — membership, blob,
    /// cache — exactly as it was, and a retry re-runs the whole op.
    /// A removal that *drains* its cluster below [`MERGE_THRESHOLD`]
    /// drops the blob outright instead of re-storing it: the follow-up
    /// merge deletes the drained cluster's blob anyway, so re-putting it
    /// here would be a wasted write (and a wasted fault surface).
    pub(crate) fn remove_chunk_deferred(&mut self, id: u32) -> Result<(bool, Option<u32>)> {
        let Some(&cluster) = self.chunk_cluster.get(&id) else {
            return Ok((false, None));
        };
        // Record-before-mutation (ahead of the blob transition too: the
        // blob store is idempotent under replay, membership is not).
        self.wal_append(&WalOp::Remove { id })?;
        // Plan (read-only): the post-removal accounting.
        let (chars_removed, new_len) = {
            let meta = &self.clusters.clusters[cluster as usize];
            let chars = match self.dynamic.get(&id) {
                Some((text, _)) => text.len() as u64,
                // Static chunk: average-out its chars from the meta (exact
                // per-chunk sizes for static chunks live in the corpus; the
                // meta keeps totals, so removal uses the cluster mean —
                // documented approximation).
                None => meta.chars / meta.len().max(1) as u64,
            };
            (chars, meta.len() - 1)
        };
        let new_chars = self.clusters.clusters[cluster as usize]
            .chars
            .saturating_sub(chars_removed);
        let new_gen = self.device.embed_gen_cost(new_chars);
        let drains = new_len < MERGE_THRESHOLD;

        // Fallible blob transition, before any mutation.
        if let Some(blob) = &self.blob {
            if !drains && new_len > 0 && new_gen > self.store_limit {
                let emb = self.gather_without(cluster, id)?;
                blob.put(cluster, &emb)?;
            } else if blob.contains(cluster) {
                blob.remove(cluster)?;
            }
        }

        // Infallible half: rewire membership and drop the stale cache
        // entry (the same invalidations `refresh_cluster` performs).
        self.update_gen.fetch_add(1, Ordering::Release);
        self.invalidate_probe_snapshot();
        self.chunk_cluster.remove(&id);
        self.dynamic.remove(&id);
        {
            let meta = &mut self.clusters.clusters[cluster as usize];
            meta.chunk_ids.retain(|&c| c != id);
            meta.chars = new_chars;
            meta.gen_cost = new_gen;
        }
        if let Some(cache) = &self.cache {
            if cache.write().unwrap().remove(cluster) {
                self.memory.lock().unwrap().release(self.cache_region(cluster));
            }
        }

        Ok((true, drains.then_some(cluster)))
    }

    /// Number of active (non-tombstone) clusters.
    pub fn active_clusters(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Cluster currently holding `chunk`.
    pub fn cluster_of(&self, chunk: u32) -> Option<u32> {
        self.chunk_cluster.get(&chunk).copied()
    }

    /// Re-derive a cluster's gen cost, cache entry and blob state after a
    /// membership change.
    fn refresh_cluster(&mut self, c: u32) -> Result<()> {
        let (gen_cost, is_empty) = {
            let meta = &mut self.clusters.clusters[c as usize];
            meta.gen_cost = self.device.embed_gen_cost(meta.chars);
            (meta.gen_cost, meta.is_empty())
        };
        // Cached embeddings are stale.
        if let Some(cache) = &self.cache {
            if cache.write().unwrap().remove(c) {
                self.memory.lock().unwrap().release(self.cache_region(c));
            }
        }
        // Selective storage re-evaluation (store / drop / refresh).
        if let Some(blob) = &self.blob {
            if !is_empty && gen_cost > self.store_limit {
                let emb = self.gather(c)?;
                blob.put(c, &emb)?;
            } else if blob.contains(c) {
                blob.remove(c)?;
            }
        }
        Ok(())
    }

    /// Split `c` in two: seeds are the two most dissimilar members, one
    /// reassignment pass, new cluster appended to the first level.
    fn split_cluster(&mut self, c: u32) -> Result<()> {
        let emb = self.gather(c)?;
        let n = emb.len();
        if n < 4 {
            return Ok(());
        }
        // Seed A: member least similar to the centroid; seed B: member
        // least similar to A.
        let centroid = self.clusters.centroids.row(c as usize).to_vec();
        let sims_c: Vec<f32> = (0..n).map(|i| vecmath::dot(emb.row(i), &centroid)).collect();
        let a = sims_c
            .iter()
            .enumerate()
            .min_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        let sims_a: Vec<f32> = (0..n).map(|i| vecmath::dot(emb.row(i), emb.row(a))).collect();
        let b = sims_a
            .iter()
            .enumerate()
            .min_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;

        let old_ids = std::mem::take(&mut self.clusters.clusters[c as usize].chunk_ids);
        let mut keep = Vec::new();
        let mut moved = Vec::new();
        let (mut sum_keep, mut sum_move) = (vec![0.0f64; emb.dim], vec![0.0f64; emb.dim]);
        for (i, id) in old_ids.into_iter().enumerate() {
            let to_a = vecmath::dot(emb.row(i), emb.row(a)) >= vecmath::dot(emb.row(i), emb.row(b));
            let (list, sum) = if to_a {
                (&mut keep, &mut sum_keep)
            } else {
                (&mut moved, &mut sum_move)
            };
            list.push(id);
            for (s, v) in sum.iter_mut().zip(emb.row(i)) {
                *s += *v as f64;
            }
        }
        if keep.is_empty() || moved.is_empty() {
            // degenerate split: restore
            let meta = &mut self.clusters.clusters[c as usize];
            meta.chunk_ids = keep.into_iter().chain(moved).collect();
            return Ok(());
        }

        let new_id = self.clusters.clusters.len() as u32;
        let mean_unit = |sum: &[f64], k: usize| -> Vec<f32> {
            let mut v: Vec<f32> = sum.iter().map(|&s| (s / k as f64) as f32).collect();
            let norm = vecmath::l2_norm(&v).max(1e-9);
            for x in &mut v {
                *x /= norm;
            }
            v
        };
        self.clusters
            .centroids
            .push(&mean_unit(&sum_move, moved.len()));
        let old_centroid = mean_unit(&sum_keep, keep.len());
        let dim = self.clusters.centroids.dim;
        self.clusters.centroids.data[c as usize * dim..(c as usize + 1) * dim]
            .copy_from_slice(&old_centroid);

        let chars_of = |index: &EdgeIndex, ids: &[u32], total: u64, all: usize| -> u64 {
            // dynamic chunks know their size; static chunks use the mean
            let mut chars = 0;
            let mean = total / all.max(1) as u64;
            for id in ids {
                chars += index
                    .dynamic
                    .get(id)
                    .map(|(t, _)| t.len() as u64)
                    .unwrap_or(mean);
            }
            chars
        };
        let total_chars = self.clusters.clusters[c as usize].chars;
        let all = keep.len() + moved.len();
        let moved_chars = chars_of(self, &moved, total_chars, all);

        for id in &moved {
            self.chunk_cluster.insert(*id, new_id);
        }
        self.clusters.clusters.push(crate::index::ClusterMeta {
            id: new_id,
            chunk_ids: moved,
            chars: moved_chars,
            gen_cost: SimDuration::ZERO,
        });
        self.active.push(true);
        {
            let meta = &mut self.clusters.clusters[c as usize];
            meta.chunk_ids = keep;
            meta.chars = total_chars.saturating_sub(moved_chars);
        }
        self.refresh_cluster(c)?;
        self.refresh_cluster(new_id)?;
        // Split is a *derived* record: replay re-derives it from the
        // parent inserts, so it is audit bookkeeping — best-effort, and
        // never un-does a committed split. The ids are parked in
        // `last_split` so a sharded wrapper (whose per-shard indexes have
        // no WAL) can emit the record with global ids instead.
        self.last_split = Some((c, new_id));
        let _ = self.wal_append(&WalOp::Split {
            cluster: c,
            new_cluster: new_id,
        });
        Ok(())
    }

    /// Snapshot everything local cluster `c` owns, for migration to
    /// another shard. Read-only (`&self`): runs under the source shard's
    /// read lease, so concurrent searches of this shard keep flowing
    /// while the copy is taken. Fails on tombstoned clusters.
    pub(crate) fn export_cluster(&self, c: u32) -> Result<ClusterExport> {
        let ci = c as usize;
        if !self.active[ci] {
            bail!("cluster {c} is tombstoned; nothing to export");
        }
        let meta = &self.clusters.clusters[ci];
        let dynamic = meta
            .chunk_ids
            .iter()
            .filter_map(|id| {
                self.dynamic
                    .get(id)
                    .map(|(t, e)| (*id, t.clone(), e.clone()))
            })
            .collect();
        let blob = match &self.blob {
            Some(b) if b.contains(c) => Some(b.get(c)?),
            _ => None,
        };
        Ok(ClusterExport {
            centroid: self.clusters.centroids.row(ci).to_vec(),
            chunk_ids: meta.chunk_ids.clone(),
            chars: meta.chars,
            gen_cost: meta.gen_cost,
            dynamic,
            blob,
            cache: self.cached_entry(c),
        })
    }

    /// Append an exported cluster as a fresh local cluster of this shard:
    /// centroid, metadata, chunk routing, dynamic overlay rows, blob and
    /// cache entry all land here. Returns the new local cluster id.
    ///
    /// The fallible blob write runs **first**, before any in-memory
    /// mutation, so a failed import leaves this shard untouched (the
    /// orphaned blob file, if any, is removed best-effort). Does **not**
    /// bump `update_gen`: nothing that existed on this shard changed, so
    /// in-flight cache intents recorded against it remain valid.
    pub(crate) fn import_cluster(&mut self, export: &ClusterExport) -> Result<u32> {
        let local = self.clusters.n_clusters() as u32;
        if let Some(emb) = &export.blob {
            let blob = self
                .blob
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("destination shard has no blob store"))?;
            blob.put(local, emb)?;
        }
        self.clusters.centroids.push(&export.centroid);
        self.clusters.clusters.push(crate::index::ClusterMeta {
            id: local,
            chunk_ids: export.chunk_ids.clone(),
            chars: export.chars,
            gen_cost: export.gen_cost,
        });
        self.active.push(true);
        for &cid in &export.chunk_ids {
            self.chunk_cluster.insert(cid, local);
        }
        for (cid, text, emb) in &export.dynamic {
            self.dynamic.insert(*cid, (text.clone(), emb.clone()));
        }
        // Re-admit the cache entry under this shard's cache (fresh use
        // counter — LFU history does not migrate; the *mass* does, which
        // is what the load accounting tracks).
        if let (Some(cache), Some((emb, lat))) = (&self.cache, &export.cache) {
            let mut c = cache.write().unwrap();
            let evicted = c.insert(local, emb.clone(), *lat);
            let mut mem = self.memory.lock().unwrap();
            for v in evicted {
                mem.release(self.cache_region(v));
            }
            // Oversized entries are declined by the cache (capacity split
            // across shards may be smaller than the source's was).
            if c.contains(local) {
                mem.install(self.cache_region(local), emb.bytes());
            }
        }
        self.invalidate_probe_snapshot();
        Ok(local)
    }

    /// Append a **tombstone** for an already-merged-away global cluster:
    /// the centroid row lands here (masked out of probes, exactly like a
    /// locally produced merge tombstone) with empty membership and no
    /// blob/cache footprint. Used by shard retirement
    /// ([`ShardedEdgeIndex::shrink_shards`](crate::index::ShardedEdgeIndex))
    /// to relocate a doomed shard's tombstones — `migrate_cluster`
    /// refuses tombstoned clusters, yet every global id must keep an
    /// owning slot for the spliced probe table to stay complete. Returns
    /// the new local id. Infallible in-memory append; does not bump
    /// `update_gen` (nothing that existed on this shard changed).
    pub(crate) fn import_tombstone(&mut self, centroid: &[f32]) -> u32 {
        let local = self.clusters.n_clusters() as u32;
        self.clusters.centroids.push(centroid);
        self.clusters.clusters.push(crate::index::ClusterMeta {
            id: local,
            chunk_ids: Vec::new(),
            chars: 0,
            gen_cost: SimDuration::ZERO,
        });
        self.active.push(false);
        self.invalidate_probe_snapshot();
        local
    }

    /// Tombstone the source copy of a migrated cluster and release every
    /// resource it held (chunk routing, dynamic overlay rows, cache entry
    /// + memory-model region, blob). Bumps `update_gen` so in-flight
    /// cache intents recorded against the pre-migration state discard
    /// their admissions instead of re-installing the retired entry.
    pub(crate) fn retire_cluster(&mut self, c: u32) -> Result<()> {
        let ci = c as usize;
        self.update_gen.fetch_add(1, Ordering::Release);
        self.invalidate_probe_snapshot();
        let ids = {
            let meta = &mut self.clusters.clusters[ci];
            meta.chars = 0;
            meta.gen_cost = SimDuration::ZERO;
            std::mem::take(&mut meta.chunk_ids)
        };
        for id in &ids {
            self.chunk_cluster.remove(id);
            self.dynamic.remove(id);
        }
        self.active[ci] = false;
        if let Some(cache) = &self.cache {
            if cache.write().unwrap().remove(c) {
                self.memory.lock().unwrap().release(self.cache_region(c));
            }
        }
        if let Some(blob) = &self.blob {
            blob.remove(c)?;
        }
        Ok(())
    }

    /// Merge a too-small cluster into its nearest active neighbour and
    /// tombstone it (the single-index / oracle path; the sharded index
    /// routes the same decision globally).
    fn merge_cluster(&mut self, c: u32) -> Result<()> {
        let Some(target) = self.merge_victim(c)? else {
            return Ok(()); // nothing to merge into
        };
        self.merge_into(c, target)
    }

    /// The nearest active neighbour a drained cluster would merge into,
    /// or None when this index has nothing else to merge into (at most
    /// one active cluster). This is the *oracle* victim choice the
    /// sharded index's global selection must reproduce bit for bit:
    /// scores of `c`'s centroid against every centroid row in ascending
    /// cluster-id order, self and tombstones masked to `-inf`, first
    /// maximum wins ([`crate::vecmath::argmax`]).
    pub fn merge_victim(&self, c: u32) -> Result<Option<u32>> {
        if self.active_clusters() <= 1 {
            return Ok(None);
        }
        let centroid = self.clusters.centroids.row(c as usize).to_vec();
        let mut scores = self.scorer.scores(&centroid, &self.clusters.centroids)?;
        scores[c as usize] = f32::NEG_INFINITY;
        for (i, s) in scores.iter_mut().enumerate() {
            if !self.active[i] {
                *s = f32::NEG_INFINITY;
            }
        }
        Ok(Some(vecmath::argmax(&scores) as u32))
    }

    /// Merge local cluster `c` into local cluster `target`, start to
    /// finish: plan (fallible, read-only), blob transition (fallible),
    /// membership rewire (infallible). Caller holds `&mut self` — the
    /// engine or shard write lease — so no search observes an
    /// intermediate state and a failure at either fallible step aborts
    /// with the index still serving its previous, consistent state.
    pub(crate) fn merge_into(&mut self, c: u32, target: u32) -> Result<()> {
        let extra = {
            let meta = &self.clusters.clusters[c as usize];
            MergeExtra {
                chars: meta.chars,
                rows: if self.blob.is_some() {
                    Some(self.gather(c)?)
                } else {
                    None
                },
                len: meta.len(),
            }
        };
        let plan = self.plan_merge(target, &extra)?;
        // Merge is a derived audit record (replay re-derives it from the
        // parent removes): best-effort, and an aborted blob step below
        // merely leaves a spurious audit line replay ignores.
        let _ = self.wal_append(&WalOp::Merge {
            source: c,
            victim: target,
        });
        self.apply_merge_blob(&plan, Some(c))?;
        self.apply_merge_members(c, &plan);
        Ok(())
    }
}

/// What a drained cluster contributes to its merge victim: member chars,
/// member count, and (when selective storage is on) its embedding rows
/// in member order — gathered on the *source* shard, which is the only
/// side that can resolve the drained cluster's dynamic overlay.
#[derive(Debug, Clone)]
pub(crate) struct MergeExtra {
    pub(crate) chars: u64,
    pub(crate) len: usize,
    pub(crate) rows: Option<EmbeddingMatrix>,
}

impl MergeExtra {
    /// Package a [`ClusterExport`]'s contribution (the cross-shard path:
    /// the export was taken on the source shard, rows included).
    pub(crate) fn from_export(export: &ClusterExport, rows: Option<EmbeddingMatrix>) -> MergeExtra {
        MergeExtra {
            chars: export.chars,
            len: export.chunk_ids.len(),
            rows,
        }
    }
}

/// The precomputed, fallible half of a merge: the victim's post-merge
/// accounting and (when selective storage applies) the combined
/// embedding blob, materialized **before** any in-memory mutation so a
/// blob failure aborts the merge cleanly. Produced by
/// [`EdgeIndex::plan_merge`]; consumed by [`EdgeIndex::apply_merge_blob`]
/// and [`EdgeIndex::apply_merge_members`].
#[derive(Debug)]
pub(crate) struct MergePlan {
    /// Local id of the absorbing cluster.
    pub(crate) target: u32,
    pub(crate) new_chars: u64,
    pub(crate) new_gen: SimDuration,
    /// The victim's post-merge blob, when its post-merge gen cost
    /// crosses the storage limit (the same `refresh_cluster` rule the
    /// inline path applies): the victim's current rows followed by the
    /// drained cluster's — exactly the member order a post-merge
    /// `gather` would produce.
    pub(crate) store: Option<EmbeddingMatrix>,
}

impl EdgeIndex {
    /// Compute a [`MergePlan`] for absorbing `extra` into local cluster
    /// `target`. Read-only and fallible (gathers the victim's rows when
    /// the post-merge state must be stored); performs no mutation.
    pub(crate) fn plan_merge(&self, target: u32, extra: &MergeExtra) -> Result<MergePlan> {
        let meta = &self.clusters.clusters[target as usize];
        let new_chars = meta.chars + extra.chars;
        let new_len = meta.len() + extra.len;
        let new_gen = self.device.embed_gen_cost(new_chars);
        let store = if self.blob.is_some() && new_len > 0 && new_gen > self.store_limit {
            let mut combined = self.gather(target)?;
            if let Some(rows) = &extra.rows {
                for i in 0..rows.len() {
                    combined.push(rows.row(i));
                }
            }
            Some(combined)
        } else {
            None
        };
        Ok(MergePlan {
            target,
            new_chars,
            new_gen,
            store,
        })
    }

    /// Apply a merge's blob transition — the only fallible step of merge
    /// execution, ordered so any failure leaves every blob consistent
    /// with the (still unmodified) membership: the drained cluster's
    /// blob is dropped first (a missing blob merely re-generates), then
    /// the victim's blob is overwritten with the combined rows or
    /// dropped per the plan. Caller holds the shard write lease, so no
    /// search observes the blob/membership transition half-applied.
    pub(crate) fn apply_merge_blob(&self, plan: &MergePlan, drained: Option<u32>) -> Result<()> {
        let Some(blob) = &self.blob else {
            return Ok(());
        };
        if let Some(c) = drained {
            if blob.contains(c) {
                blob.remove(c)?;
            }
        }
        match &plan.store {
            Some(combined) => blob.put(plan.target, combined)?,
            None => {
                if blob.contains(plan.target) {
                    blob.remove(plan.target)?;
                }
            }
        }
        Ok(())
    }

    /// The infallible half of a merge: move the drained cluster's
    /// members (appended in order, exactly as the inline path extends),
    /// install the planned accounting on the victim, tombstone the
    /// drained cluster and drop both clusters' cache entries (the
    /// victim's embeddings are stale, the drained cluster's are gone —
    /// the same invalidations `refresh_cluster` performs inline). Bumps
    /// `update_gen` so in-flight cache admissions recorded against the
    /// pre-merge state are discarded at commit.
    pub(crate) fn apply_merge_members(&mut self, c: u32, plan: &MergePlan) {
        self.update_gen.fetch_add(1, Ordering::Release);
        self.invalidate_probe_snapshot();
        let ids = {
            let meta = &mut self.clusters.clusters[c as usize];
            meta.chars = 0;
            meta.gen_cost = SimDuration::ZERO;
            std::mem::take(&mut meta.chunk_ids)
        };
        for id in &ids {
            self.chunk_cluster.insert(*id, plan.target);
        }
        {
            let meta = &mut self.clusters.clusters[plan.target as usize];
            meta.chunk_ids.extend(ids);
            meta.chars = plan.new_chars;
            meta.gen_cost = plan.new_gen;
        }
        self.active[c as usize] = false;
        if let Some(cache) = &self.cache {
            let mut cw = cache.write().unwrap();
            if cw.remove(c) {
                self.memory.lock().unwrap().release(self.cache_region(c));
            }
            if cw.remove(plan.target) {
                self.memory
                    .lock()
                    .unwrap()
                    .release(self.cache_region(plan.target));
            }
        }
    }

    /// Export a drained cluster for a cross-shard merge: like
    /// [`EdgeIndex::export_cluster`] but without the blob and cache
    /// payloads (the merge deletes both anyway — nothing to hand off)
    /// and with the cluster's embedding rows gathered here, on the only
    /// shard that can resolve its dynamic overlay. Read-only.
    pub(crate) fn export_for_merge(
        &self,
        c: u32,
    ) -> Result<(ClusterExport, Option<EmbeddingMatrix>)> {
        let ci = c as usize;
        if !self.active[ci] {
            bail!("cluster {c} is tombstoned; nothing to merge");
        }
        let meta = &self.clusters.clusters[ci];
        let dynamic = meta
            .chunk_ids
            .iter()
            .filter_map(|id| {
                self.dynamic
                    .get(id)
                    .map(|(t, e)| (*id, t.clone(), e.clone()))
            })
            .collect();
        let export = ClusterExport {
            centroid: self.clusters.centroids.row(ci).to_vec(),
            chunk_ids: meta.chunk_ids.clone(),
            chars: meta.chars,
            gen_cost: meta.gen_cost,
            dynamic,
            blob: None,
            cache: None,
        };
        let rows = if self.blob.is_some() {
            Some(self.gather(c)?)
        } else {
            None
        };
        Ok((export, rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetProfile, DeviceProfile, IndexKind, RetrievalConfig};
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::{shared_memory, ClusterSet, EmbedSource, Scorer, VectorIndex};
    use crate::storage::BlobStore;
    use crate::testutil::shared_compute;
    use std::sync::Arc;

    struct Fx {
        corpus: Corpus,
        embedder: Embedder,
        idx: EdgeIndex,
    }

    fn fixture(tag: &str) -> Fx {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        let scorer = Scorer::new(compute);
        let km = kmeans(
            &emb,
            &KMeansConfig {
                n_clusters: 8,
                iterations: 5,
                seed: 1,
                init: None,
            },
            &scorer,
        )
        .unwrap();
        let device = DeviceProfile::jetson_orin_nano();
        let set = ClusterSet::build(&corpus, km.centroids, &km.assignment, &device);
        let dir = std::env::temp_dir().join(format!("edgerag-upd-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let blob = BlobStore::open(&dir, scorer.dim()).unwrap();
        let idx = EdgeIndex::build(
            IndexKind::EdgeRag,
            set,
            EmbedSource::Prebuilt(emb),
            Some(blob),
            scorer,
            shared_memory(64 << 20),
            device,
            &RetrievalConfig {
                nprobe: 4,
                ..Default::default()
            },
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
        )
        .unwrap();
        Fx {
            corpus,
            embedder,
            idx,
        }
    }

    #[test]
    fn inserted_chunk_is_retrievable() {
        let mut f = fixture("insert");
        let text = "a brand new document about retrieval on edge devices \
                    with very distinctive tokens zzqx yyqw xxqe";
        let emb = f.embedder.embed_one(text).unwrap();
        let new_id = f.corpus.len() as u32 + 100;
        let cluster = f.idx.insert_chunk(new_id, text, &emb).unwrap();
        assert_eq!(f.idx.cluster_of(new_id), Some(cluster));
        // Searching with the chunk's own embedding must find it.
        let out = f.idx.search(&emb, 3).unwrap();
        assert_eq!(out.hits[0].0, new_id, "hits: {:?}", out.hits);
    }

    #[test]
    fn duplicate_insert_rejected() {
        let mut f = fixture("dupe");
        let emb = f.embedder.embed_one("x").unwrap();
        assert!(f.idx.insert_chunk(0, "x", &emb).is_err());
    }

    #[test]
    fn removed_chunk_no_longer_retrieved() {
        let mut f = fixture("remove");
        let victim = 42u32;
        let q = f.embedder.embed_one(&f.corpus.chunks[victim as usize].text).unwrap();
        let before = f.idx.search(&q, 5).unwrap();
        assert!(before.hits.iter().any(|h| h.0 == victim));
        assert!(f.idx.remove_chunk(victim).unwrap());
        let after = f.idx.search(&q, 5).unwrap();
        assert!(!after.hits.iter().any(|h| h.0 == victim));
        assert_eq!(f.idx.cluster_of(victim), None);
        assert!(!f.idx.remove_chunk(victim).unwrap(), "second remove is a no-op");
    }

    #[test]
    fn insertion_updates_gen_cost_and_storage() {
        let mut f = fixture("grow");
        // Find a cluster just below the storage limit and grow it past it.
        let limit = SimDuration::from_millis(150);
        let target = f
            .idx
            .clusters
            .clusters
            .iter()
            .find(|m| m.gen_cost < limit && m.len() > 4)
            .map(|m| (m.id, m.gen_cost))
            .expect("need a light cluster");
        assert!(!f.idx.blob.as_ref().unwrap().contains(target.0));
        // Insert big chunks near that cluster's centroid until it crosses.
        let centroid_text: String = {
            let member = f.idx.clusters.clusters[target.0 as usize].chunk_ids[0];
            f.corpus.chunks[member as usize].text.clone()
        };
        let mut next_id = 10_000u32;
        for _ in 0..40 {
            let text = format!("{centroid_text} {}", "pad ".repeat(128));
            let emb = f.embedder.embed_one(&text).unwrap();
            // Route explicitly into the target cluster's neighbourhood.
            f.idx.insert_chunk(next_id, &text, &emb).unwrap();
            next_id += 1;
            if f.idx.clusters.clusters[target.0 as usize].gen_cost > limit {
                break;
            }
        }
        // Some cluster must have crossed the limit and been persisted.
        let any_stored_after: usize = f.idx.stored_clusters();
        assert!(any_stored_after > 0);
    }

    #[test]
    fn merge_tombstones_cluster() {
        let mut f = fixture("merge");
        // Drain a small cluster below the merge threshold.
        let small = f
            .idx
            .clusters
            .clusters
            .iter()
            .min_by_key(|m| m.len())
            .map(|m| (m.id, m.chunk_ids.clone()))
            .unwrap();
        let before_active = f.idx.active_clusters();
        for id in &small.1 {
            f.idx.remove_chunk(*id).unwrap();
        }
        assert!(f.idx.active_clusters() < before_active);
        // Remaining chunks of the merged cluster now route elsewhere, and
        // search still works.
        let q = f.embedder.embed_one(&f.corpus.chunks[0].text).unwrap();
        let out = f.idx.search(&q, 3).unwrap();
        assert!(!out.hits.is_empty());
        for h in &out.hits {
            assert!(f.idx.cluster_of(h.0).is_some());
        }
    }

    #[test]
    fn split_keeps_all_chunks_routed() {
        let mut f = fixture("split");
        // Force a split by shrinking the threshold indirectly: insert many
        // chunks into one cluster. SPLIT_THRESHOLD is large, so instead
        // call split directly on the biggest cluster.
        let big = f
            .idx
            .clusters
            .clusters
            .iter()
            .max_by_key(|m| m.len())
            .unwrap()
            .id;
        let members_before: usize = f.idx.clusters.clusters[big as usize].len();
        assert!(members_before >= 4);
        f.idx.split_cluster(big).unwrap();
        let n = f.idx.clusters.clusters.len();
        let new_id = (n - 1) as u32;
        let a = f.idx.clusters.clusters[big as usize].len();
        let b = f.idx.clusters.clusters[new_id as usize].len();
        assert_eq!(a + b, members_before);
        assert!(a > 0 && b > 0);
        // routing table consistent
        for meta in [big, new_id] {
            for &cid in &f.idx.clusters.clusters[meta as usize].chunk_ids {
                assert_eq!(f.idx.cluster_of(cid), Some(meta));
            }
        }
        // search still retrieves split members
        let member = f.idx.clusters.clusters[new_id as usize].chunk_ids[0];
        let q = f.embedder.embed_one(&f.corpus.chunks[member as usize].text).unwrap();
        let out = f.idx.search(&q, 5).unwrap();
        assert!(out.hits.iter().any(|h| h.0 == member));
    }
}
