//! Vector indexes: the flat baseline, the two-level IVF baseline, and the
//! EdgeRAG index (pruned second level + online generation + selective
//! storage + adaptive cache). One implementation per row of paper Table 4.
//!
//! ## Concurrency model
//!
//! `search` takes `&self` so any number of queries can execute in
//! parallel against a shared index. Searches are *pure reads* of index
//! structure: the mutations EdgeRAG used to perform inline (cache
//! admission, use-counter bumps, adaptive-threshold feedback) are instead
//! **recorded** into the [`CacheIntent`]s carried by each
//! [`SearchOutcome`] and **applied** afterwards through the separate
//! [`VectorIndex::commit`] path. A search returns one intent per index
//! shard it touched (a single-shard [`EdgeIndex`] always returns exactly
//! one); each intent is committed independently under only its own
//! shard's locks. Structural mutations (online insert/remove, threshold
//! pinning) require `&mut self` on [`EdgeIndex`]; the sharded index
//! ([`ShardedEdgeIndex`]) scopes them to the owning shard's write lease
//! so a query and an insert to different shards overlap.
//!
//! The full lock hierarchy (engine lease → shard lease → controller →
//! cache → memory model) is documented in `docs/ARCHITECTURE.md`.

pub mod clusters;
pub mod edge;
pub mod flat;
pub mod ivf;
pub mod kmeans;
pub mod rebalance;
pub mod scorer;
pub mod shard;
pub mod updates;

use std::sync::{Arc, Mutex};

use anyhow::Result;

pub use clusters::{ClusterMeta, ClusterSet, EmbedSource};
pub use edge::EdgeIndex;
pub use flat::FlatIndex;
pub use ivf::IvfIndex;
pub use rebalance::{
    plan_rebalance, ClusterLoad, MigrationMove, MigrationPlan, RebalanceReport, ReshardReport,
    HEAT_WEIGHT,
};
pub use scorer::Scorer;
pub use shard::{ShardStats, ShardedEdgeIndex};

use crate::cache::CacheStats;
use crate::config::IndexKind;
use crate::simtime::{LatencyLedger, SimDuration};
use crate::storage::{MemoryModel, WalActivity};
use crate::vecmath::EmbeddingMatrix;

/// Memory model shared between an index and the LLM side of the pipeline
/// (they contend for the same device DRAM — that contention *is* the
/// paper's Fig. 3 phenomenon).
pub type SharedMemory = Arc<Mutex<MemoryModel>>;

pub fn shared_memory(capacity: u64) -> SharedMemory {
    Arc::new(Mutex::new(MemoryModel::new(capacity)))
}

/// Event counts of one search (feeds Fig. 6/12 style analyses).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchEvents {
    /// Clusters whose embeddings were generated online.
    pub generated: usize,
    /// Clusters loaded from the precomputed blob store.
    pub loaded: usize,
    /// Cluster embedding cache hits.
    pub cache_hits: usize,
    /// Residency faults charged (memory thrash events).
    pub thrash_faults: usize,
}

/// One shard's cluster-walk record from a single search — which shard
/// ran, how many clusters it walked, how long the walk took on the wall
/// clock, and how its cluster embeddings were sourced. Collected only
/// when tracing is enabled (the vector stays empty otherwise, costing
/// nothing); the engine converts these into per-shard trace spans after
/// the search returns, because the walks themselves run on pool worker
/// threads that do not carry the query's thread-local trace.
#[derive(Debug, Clone, Copy)]
pub struct ShardWalk {
    /// Shard index (0 for an unsharded index).
    pub shard: u32,
    /// Clusters this shard walked.
    pub clusters: u32,
    /// Wall-clock nanoseconds of the walk on its worker thread.
    pub walk_ns: u64,
    /// Clusters whose embeddings were generated online.
    pub generated: u32,
    /// Clusters loaded from the blob store.
    pub loaded: u32,
    /// Cluster embedding cache hits.
    pub cache_hits: u32,
}

/// A freshly generated cluster the search proposes for cache admission.
#[derive(Debug, Clone)]
pub struct AdmitCandidate {
    pub cluster: u32,
    /// The generated embeddings (shared, not copied, into the cache).
    pub emb: Arc<EmbeddingMatrix>,
    /// Profiled generation latency in ms — the cost weight and the value
    /// the adaptive threshold gates on.
    pub gen_latency_ms: f64,
}

/// One cache probe observed during a search, in probe order. Replaying
/// hits (counter bump) and misses (decay-epoch advance) in this exact
/// order reproduces Algorithm 2's single-threaded LFU state.
#[derive(Debug, Clone, Copy)]
pub enum CacheAccess {
    Hit(u32),
    Miss,
}

/// Deferred cache mutations recorded by a read-only search and applied by
/// [`VectorIndex::commit`]. Baseline indexes produce none.
///
/// One intent covers exactly one index shard: replaying it takes only
/// that shard's controller/cache locks, so a sharded search's intents
/// commit independently (and a plain [`EdgeIndex`] search yields a single
/// intent with `shard == 0`).
#[derive(Debug, Clone, Default)]
pub struct CacheIntent {
    /// Which shard's cache/threshold state this intent belongs to
    /// (always 0 for an unsharded [`EdgeIndex`]).
    pub shard: usize,
    /// Ordered cache probes: hits bump their LFU counters at commit time,
    /// misses advance the decay epoch.
    pub accesses: Vec<CacheAccess>,
    /// Generated clusters proposed for admission (threshold-gated).
    pub admit: Vec<AdmitCandidate>,
    /// Did this search miss the cache at least once? (Alg. 3 input.)
    pub had_miss: bool,
    /// Shard update-generation observed at search time; commit discards
    /// admissions if an insert/remove landed in between (their embeddings
    /// could be stale).
    pub generation: u64,
}

/// A lock-free snapshot of an index's first level: every centroid row in
/// ascending *global* cluster-id order, its global id, and a tombstone
/// mask. Probing — including cross-query batched probing through the
/// scheduler ([`crate::sched`]) — scores against this snapshot without
/// taking any index or shard lease, so a probing query never queues
/// behind an in-flight structural update.
///
/// Snapshots are invalidated by structural updates and rebuilt lazily on
/// the next probe. Staleness semantics differ by index:
///
/// * **Sharded** ([`ShardedEdgeIndex`]): a query probing a
///   just-superseded snapshot behaves exactly like a query that probed
///   before the update landed — the same bounded race the sharded
///   lease-based probe always had between its probe and its cluster
///   walks (cluster ids are never reused, so stale ids stay valid and
///   tombstoned clusters walk as empty).
/// * **Single-shard** ([`EdgeIndex`]): the lease-based path probes and
///   walks under one continuous engine read lease, so no such race ever
///   existed there. To preserve that model,
///   [`VectorIndex::search_with_scores`] on an [`EdgeIndex`] checks the
///   snapshot's `generation` against the live update counter and falls
///   back to a fresh in-lease probe when an update slipped in between.
#[derive(Debug, Clone)]
pub struct ProbeTable {
    /// Centroid rows, one per (live or tombstoned) cluster, in ascending
    /// global-id order — the exact traversal order the lease-based probe
    /// scored in, so `top_k`'s lower-index tie preference is preserved.
    pub centroids: EmbeddingMatrix,
    /// Global cluster id of each row.
    pub ids: Vec<u32>,
    /// Liveness per row; tombstoned rows are masked to `-inf`.
    pub active: Vec<bool>,
    /// Total first-level bytes (including tombstones) for the modeled
    /// [`crate::simtime::Component::CentroidProbe`] charge — identical to
    /// what the lease-based probe charged.
    pub centroid_bytes: u64,
    /// Structural-update generation this snapshot was built at (the
    /// owning index's counter; the single-shard staleness fence above).
    pub generation: u64,
}

impl ProbeTable {
    /// Number of centroid rows (live + tombstoned).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Scores of `query` against every row, tombstones masked to `-inf`.
    /// Bit-identical to the lease-based probe: the same scorer computes
    /// the same per-row inner products in the same order.
    pub fn masked_scores(&self, scorer: &Scorer, query: &[f32]) -> Result<Vec<f32>> {
        let mut scores = scorer.scores(query, &self.centroids)?;
        self.mask(&mut scores);
        Ok(scores)
    }

    /// Apply the tombstone mask to a raw score vector.
    pub fn mask(&self, scores: &mut [f32]) {
        for (s, &a) in scores.iter_mut().zip(&self.active) {
            if !a {
                *s = f32::NEG_INFINITY;
            }
        }
    }
}

/// Result of one vector search.
#[derive(Debug, Clone, Default)]
pub struct SearchOutcome {
    /// (chunk id, score), descending.
    pub hits: Vec<(u32, f32)>,
    /// Modeled device-time breakdown of this search.
    pub ledger: LatencyLedger,
    /// Which clusters were probed (empty for flat). For a sharded index
    /// these are *global* cluster ids (`local × shards + shard`).
    pub probed: Vec<u32>,
    pub events: SearchEvents,
    /// Deferred cache mutations to apply through [`VectorIndex::commit`]:
    /// one [`CacheIntent`] per shard the search probed (at most one for
    /// unsharded indexes, empty for the baselines).
    pub intents: Vec<CacheIntent>,
    /// Per-shard walk records for trace attribution. Populated only when
    /// tracing is enabled; empty (no allocation) otherwise.
    pub shard_walks: Vec<ShardWalk>,
}

/// The interface all five Table-4 configurations serve behind.
///
/// `Send + Sync` because the serving engine shares one index across its
/// worker pool: reads go through `&self`, writes take an exclusive lease.
///
/// Beyond `search`/`commit`, the trait carries default-implemented
/// accessors for the EdgeRAG-specific serving state (cache statistics,
/// adaptive threshold, online updates, per-shard rows) so the engine,
/// server and harness talk to one interface instead of downcasting to
/// `EdgeIndex`-vs-`ShardedEdgeIndex`; the baselines inherit the inert
/// defaults.
pub trait VectorIndex: Send + Sync {
    fn kind(&self) -> IndexKind;

    /// Search for the `k` most similar chunks to an (already embedded)
    /// query vector. Read-only: concurrent calls are safe and do not
    /// block each other on cache or threshold state.
    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome>;

    /// Apply one search's deferred cache mutations plus the adaptive
    /// threshold feedback (paper Alg. 3 observes the query's total
    /// retrieval latency). Each intent is applied independently under its
    /// own shard's locks. No-op for baselines.
    fn commit(&self, _intents: &[CacheIntent], _retrieval: SimDuration) {}

    /// Bytes this configuration keeps memory-resident for the index
    /// itself (Fig. 3's "embedded database size" bars).
    fn resident_bytes(&self) -> u64;

    /// Downcast support for shared references (read-only stats paths).
    fn as_any(&self) -> &dyn std::any::Any;

    /// Downcast support for the write path (the harness reaches
    /// EdgeRAG-specific state — online updates, threshold pinning —
    /// through the trait object).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    // ---- EdgeRAG-family serving state (inert defaults for baselines) ----

    /// Aggregate embedding-cache statistics (None when this configuration
    /// has no cache).
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }

    /// Bytes resident in the embedding cache(s).
    fn cache_used_bytes(&self) -> u64 {
        0
    }

    /// Cluster ids (global) currently resident in the embedding cache(s),
    /// sorted — equivalence tests and the stats endpoint.
    fn cached_clusters(&self) -> Vec<u32> {
        Vec::new()
    }

    /// Clusters persisted in blob storage (selective storage).
    fn stored_clusters(&self) -> usize {
        0
    }

    /// Bytes persisted in blob storage.
    fn stored_bytes(&self) -> u64 {
        0
    }

    /// Current adaptive caching threshold in ms (mean across shards for a
    /// sharded index; 0 for configurations without a cache).
    fn threshold_ms(&self) -> f64 {
        0.0
    }

    /// Pin the caching threshold and disable adaptation (Fig. 7 sweeps).
    /// No-op for configurations without a cache.
    fn pin_threshold(&mut self, _threshold_ms: f64) {}

    /// Per-shard serving rows (None when the index is not sharded).
    fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        None
    }

    /// Run one online cross-shard rebalance round (see
    /// [`crate::index::rebalance`]). Inert for unsharded configurations:
    /// there is nothing to move, so the default reports zero planned and
    /// zero migrated.
    fn rebalance(&self) -> Result<RebalanceReport> {
        Ok(RebalanceReport::default())
    }

    /// Change the live shard count to `target` by growing (empty shards
    /// appended) or shrinking (drain-then-retire). Only the sharded index
    /// supports elastic topology; everything else rejects the op so the
    /// server can surface a clean error instead of silently ignoring it.
    fn reshard(&self, _target: usize) -> Result<ReshardReport> {
        anyhow::bail!("index is not sharded; reshard is unsupported")
    }

    /// Flush the structural write-ahead log's snapshot (consolidating
    /// the log into the snapshot and truncating the tail) — the server's
    /// clean-shutdown hook. Inert for configurations without a WAL.
    fn wal_checkpoint(&self) -> Result<()> {
        Ok(())
    }

    /// Write-ahead-log activity counters (None for configurations
    /// without a WAL, or when the WAL is disabled).
    fn wal_stats(&self) -> Option<WalActivity> {
        None
    }

    /// Probe-snapshot rebuilds performed since construction (lazy
    /// rebuilds after structural updates; 0 for indexes without a
    /// centroid snapshot).
    fn probe_rebuilds(&self) -> u64 {
        0
    }

    // ---- online updates (§5.4) ----

    /// True when [`VectorIndex::insert_chunk_concurrent`] /
    /// [`VectorIndex::remove_chunk_concurrent`] are supported, i.e. the
    /// index scopes structural updates internally (per-shard write
    /// leases) and may be mutated through `&self`.
    fn supports_concurrent_updates(&self) -> bool {
        false
    }

    /// Insert a chunk under an exclusive lease. Errors for configurations
    /// without online updates (the baselines).
    fn insert_chunk(&mut self, _id: u32, _text: &str, _emb: &[f32]) -> Result<u32> {
        anyhow::bail!("{} index does not support online insertion", self.kind().name())
    }

    /// Remove a chunk under an exclusive lease. Errors for configurations
    /// without online updates.
    fn remove_chunk(&mut self, _id: u32) -> Result<bool> {
        anyhow::bail!("{} index does not support online removal", self.kind().name())
    }

    /// Shard-scoped insert through a shared reference (sharded indexes
    /// only — see [`VectorIndex::supports_concurrent_updates`]).
    fn insert_chunk_concurrent(&self, _id: u32, _text: &str, _emb: &[f32]) -> Result<u32> {
        anyhow::bail!("index does not support concurrent insertion")
    }

    /// Shard-scoped remove through a shared reference.
    fn remove_chunk_concurrent(&self, _id: u32) -> Result<bool> {
        anyhow::bail!("index does not support concurrent removal")
    }

    // ---- batched probing (the cross-query scheduler's hooks) ----

    /// A lock-free snapshot of the first level for (possibly cross-query
    /// batched) centroid scoring, or None when this index has no
    /// centroid level (flat baseline). See [`ProbeTable`].
    fn probe_table(&self) -> Option<Arc<ProbeTable>> {
        None
    }

    /// Search using centroid scores a caller already computed against
    /// [`VectorIndex::probe_table`] (`scores[i]` scores `table.ids[i]`,
    /// tombstones masked). Must return exactly what [`VectorIndex::search`]
    /// returns for the same query when the table is current. The default
    /// ignores the precomputed scores and re-searches.
    fn search_with_scores(
        &self,
        query: &[f32],
        _table: &ProbeTable,
        _scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        self.search(query, k)
    }
}
