//! Vector indexes: the flat baseline, the two-level IVF baseline, and the
//! EdgeRAG index (pruned second level + online generation + selective
//! storage + adaptive cache). One implementation per row of paper Table 4.

pub mod clusters;
pub mod edge;
pub mod flat;
pub mod ivf;
pub mod kmeans;
pub mod scorer;
pub mod updates;

use std::sync::{Arc, Mutex};

use anyhow::Result;

pub use clusters::{ClusterMeta, ClusterSet, EmbedSource};
pub use edge::EdgeIndex;
pub use flat::FlatIndex;
pub use ivf::IvfIndex;
pub use scorer::Scorer;

use crate::config::IndexKind;
use crate::simtime::{LatencyLedger, SimDuration};
use crate::storage::MemoryModel;

/// Memory model shared between an index and the LLM side of the pipeline
/// (they contend for the same device DRAM — that contention *is* the
/// paper's Fig. 3 phenomenon).
pub type SharedMemory = Arc<Mutex<MemoryModel>>;

pub fn shared_memory(capacity: u64) -> SharedMemory {
    Arc::new(Mutex::new(MemoryModel::new(capacity)))
}

/// Event counts of one search (feeds Fig. 6/12 style analyses).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchEvents {
    /// Clusters whose embeddings were generated online.
    pub generated: usize,
    /// Clusters loaded from the precomputed blob store.
    pub loaded: usize,
    /// Cluster embedding cache hits.
    pub cache_hits: usize,
    /// Residency faults charged (memory thrash events).
    pub thrash_faults: usize,
}

/// Result of one vector search.
#[derive(Debug, Clone, Default)]
pub struct SearchOutcome {
    /// (chunk id, score), descending.
    pub hits: Vec<(u32, f32)>,
    /// Modeled device-time breakdown of this search.
    pub ledger: LatencyLedger,
    /// Which clusters were probed (empty for flat).
    pub probed: Vec<u32>,
    pub events: SearchEvents,
}

/// The interface all five Table-4 configurations serve behind.
pub trait VectorIndex: Send {
    fn kind(&self) -> IndexKind;

    /// Search for the `k` most similar chunks to an (already embedded)
    /// query vector.
    fn search(&mut self, query: &[f32], k: usize) -> Result<SearchOutcome>;

    /// Bytes this configuration keeps memory-resident for the index
    /// itself (Fig. 3's "embedded database size" bars).
    fn resident_bytes(&self) -> u64;

    /// Post-retrieval feedback with the query's total retrieval latency
    /// (drives EdgeRAG's adaptive caching threshold; no-op for baselines).
    fn feedback(&mut self, _retrieval: SimDuration) {}

    /// Downcast support (the harness reaches EdgeRAG-specific state —
    /// cache stats, threshold pinning — through the trait object).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}
