//! Vector indexes: the flat baseline, the two-level IVF baseline, and the
//! EdgeRAG index (pruned second level + online generation + selective
//! storage + adaptive cache). One implementation per row of paper Table 4.
//!
//! ## Concurrency model
//!
//! `search` takes `&self` so any number of queries can execute in
//! parallel against a shared index. Searches are *pure reads* of index
//! structure: the mutations EdgeRAG used to perform inline (cache
//! admission, use-counter bumps, adaptive-threshold feedback) are instead
//! **recorded** into the [`CacheIntent`]s carried by each
//! [`SearchOutcome`] and **applied** afterwards through the separate
//! [`VectorIndex::commit`] path. A search returns one intent per index
//! shard it touched (a single-shard [`EdgeIndex`] always returns exactly
//! one); each intent is committed independently under only its own
//! shard's locks. Structural mutations (online insert/remove, threshold
//! pinning) require `&mut self` on [`EdgeIndex`]; the sharded index
//! ([`ShardedEdgeIndex`]) scopes them to the owning shard's write lease
//! so a query and an insert to different shards overlap.
//!
//! The full lock hierarchy (engine lease → shard lease → controller →
//! cache → memory model) is documented in `docs/ARCHITECTURE.md`.

pub mod clusters;
pub mod edge;
pub mod flat;
pub mod ivf;
pub mod kmeans;
pub mod scorer;
pub mod shard;
pub mod updates;

use std::sync::{Arc, Mutex};

use anyhow::Result;

pub use clusters::{ClusterMeta, ClusterSet, EmbedSource};
pub use edge::EdgeIndex;
pub use flat::FlatIndex;
pub use ivf::IvfIndex;
pub use scorer::Scorer;
pub use shard::ShardedEdgeIndex;

use crate::config::IndexKind;
use crate::simtime::{LatencyLedger, SimDuration};
use crate::storage::MemoryModel;
use crate::vecmath::EmbeddingMatrix;

/// Memory model shared between an index and the LLM side of the pipeline
/// (they contend for the same device DRAM — that contention *is* the
/// paper's Fig. 3 phenomenon).
pub type SharedMemory = Arc<Mutex<MemoryModel>>;

pub fn shared_memory(capacity: u64) -> SharedMemory {
    Arc::new(Mutex::new(MemoryModel::new(capacity)))
}

/// Event counts of one search (feeds Fig. 6/12 style analyses).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchEvents {
    /// Clusters whose embeddings were generated online.
    pub generated: usize,
    /// Clusters loaded from the precomputed blob store.
    pub loaded: usize,
    /// Cluster embedding cache hits.
    pub cache_hits: usize,
    /// Residency faults charged (memory thrash events).
    pub thrash_faults: usize,
}

/// A freshly generated cluster the search proposes for cache admission.
#[derive(Debug, Clone)]
pub struct AdmitCandidate {
    pub cluster: u32,
    /// The generated embeddings (shared, not copied, into the cache).
    pub emb: Arc<EmbeddingMatrix>,
    /// Profiled generation latency in ms — the cost weight and the value
    /// the adaptive threshold gates on.
    pub gen_latency_ms: f64,
}

/// One cache probe observed during a search, in probe order. Replaying
/// hits (counter bump) and misses (decay-epoch advance) in this exact
/// order reproduces Algorithm 2's single-threaded LFU state.
#[derive(Debug, Clone, Copy)]
pub enum CacheAccess {
    Hit(u32),
    Miss,
}

/// Deferred cache mutations recorded by a read-only search and applied by
/// [`VectorIndex::commit`]. Baseline indexes produce none.
///
/// One intent covers exactly one index shard: replaying it takes only
/// that shard's controller/cache locks, so a sharded search's intents
/// commit independently (and a plain [`EdgeIndex`] search yields a single
/// intent with `shard == 0`).
#[derive(Debug, Clone, Default)]
pub struct CacheIntent {
    /// Which shard's cache/threshold state this intent belongs to
    /// (always 0 for an unsharded [`EdgeIndex`]).
    pub shard: usize,
    /// Ordered cache probes: hits bump their LFU counters at commit time,
    /// misses advance the decay epoch.
    pub accesses: Vec<CacheAccess>,
    /// Generated clusters proposed for admission (threshold-gated).
    pub admit: Vec<AdmitCandidate>,
    /// Did this search miss the cache at least once? (Alg. 3 input.)
    pub had_miss: bool,
    /// Shard update-generation observed at search time; commit discards
    /// admissions if an insert/remove landed in between (their embeddings
    /// could be stale).
    pub generation: u64,
}

/// Result of one vector search.
#[derive(Debug, Clone, Default)]
pub struct SearchOutcome {
    /// (chunk id, score), descending.
    pub hits: Vec<(u32, f32)>,
    /// Modeled device-time breakdown of this search.
    pub ledger: LatencyLedger,
    /// Which clusters were probed (empty for flat). For a sharded index
    /// these are *global* cluster ids (`local × shards + shard`).
    pub probed: Vec<u32>,
    pub events: SearchEvents,
    /// Deferred cache mutations to apply through [`VectorIndex::commit`]:
    /// one [`CacheIntent`] per shard the search probed (at most one for
    /// unsharded indexes, empty for the baselines).
    pub intents: Vec<CacheIntent>,
}

/// The interface all five Table-4 configurations serve behind.
///
/// `Send + Sync` because the serving engine shares one index across its
/// worker pool: reads go through `&self`, writes take an exclusive lease.
pub trait VectorIndex: Send + Sync {
    fn kind(&self) -> IndexKind;

    /// Search for the `k` most similar chunks to an (already embedded)
    /// query vector. Read-only: concurrent calls are safe and do not
    /// block each other on cache or threshold state.
    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome>;

    /// Apply one search's deferred cache mutations plus the adaptive
    /// threshold feedback (paper Alg. 3 observes the query's total
    /// retrieval latency). Each intent is applied independently under its
    /// own shard's locks. No-op for baselines.
    fn commit(&self, _intents: &[CacheIntent], _retrieval: SimDuration) {}

    /// Bytes this configuration keeps memory-resident for the index
    /// itself (Fig. 3's "embedded database size" bars).
    fn resident_bytes(&self) -> u64;

    /// Downcast support for shared references (read-only stats paths).
    fn as_any(&self) -> &dyn std::any::Any;

    /// Downcast support for the write path (the harness reaches
    /// EdgeRAG-specific state — online updates, threshold pinning —
    /// through the trait object).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}
