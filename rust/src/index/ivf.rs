//! Two-level IVF baseline (paper §2.3, Table 4 row "IVF"): first-level
//! centroids + *all* second-level embeddings kept in memory. Fast when the
//! database fits; thrashes catastrophically when it doesn't — the paper's
//! primary comparison point.

use anyhow::Result;

use crate::config::{DeviceProfile, IndexKind};
use crate::index::{ClusterSet, Scorer, SearchEvents, SearchOutcome, SharedMemory, VectorIndex};
use crate::simtime::{Component, LatencyLedger};
use crate::storage::Region;
use crate::vecmath::{self, EmbeddingMatrix};

/// The fully-resident two-level baseline (Table 4 row "IVF").
pub struct IvfIndex {
    clusters: ClusterSet,
    /// Second-level embeddings per cluster — resident by design.
    cluster_embs: Vec<EmbeddingMatrix>,
    scorer: Scorer,
    memory: SharedMemory,
    device: DeviceProfile,
    nprobe: usize,
}

impl IvfIndex {
    /// Assemble from a cluster set plus its per-cluster embeddings; call
    /// [`IvfIndex::preload`] to model their residency.
    pub fn new(
        clusters: ClusterSet,
        cluster_embs: Vec<EmbeddingMatrix>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
        nprobe: usize,
    ) -> Self {
        assert_eq!(clusters.n_clusters(), cluster_embs.len());
        IvfIndex {
            clusters,
            cluster_embs,
            scorer,
            memory,
            device,
            nprobe,
        }
    }

    /// The shared two-level structure (centroids + per-cluster metadata).
    pub fn clusters(&self) -> &ClusterSet {
        &self.clusters
    }

    /// Override the probe width (harness sweeps).
    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe;
    }

    /// Load the whole second level into (modeled) memory — the IVF
    /// baseline's startup premise (Table 4: embeddings in Memory). When
    /// the index exceeds the budget this fills memory and the LRU churns
    /// from the first query (steady-state thrash, not cold-start faults).
    pub fn preload(&self) {
        let dim = self.scorer.dim();
        let mut mem = self.memory.lock().unwrap();
        for meta in &self.clusters.clusters {
            if !meta.is_empty() {
                mem.touch(Region::Cluster(meta.id), meta.emb_bytes(dim));
            }
        }
    }
}

impl VectorIndex for IvfIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Ivf
    }

    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let mut ledger = LatencyLedger::new();
        let mut events = SearchEvents::default();
        let dim = self.scorer.dim();

        // Level 1: centroid probe (centroids are always resident).
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(self.clusters.centroid_bytes()),
        );
        let probes = self
            .scorer
            .top_k(query, &self.clusters.centroids, self.nprobe)?;

        // Level 2: per-cluster in-memory search; non-resident clusters
        // fault in scattered (mmap-style page-ins — the thrash case).
        let mut all_hits: Vec<(u32, f32)> = Vec::new();
        let mut probed = Vec::with_capacity(probes.len());
        for (c, _) in probes {
            let meta = &self.clusters.clusters[c];
            probed.push(c as u32);
            if meta.is_empty() {
                continue;
            }
            let bytes = meta.emb_bytes(dim);
            let faulted = self.memory.lock().unwrap().touch(Region::Cluster(c as u32), bytes);
            if faulted > 0 {
                events.thrash_faults += 1;
                ledger.charge(Component::Thrash, self.device.thrash_cost(faulted));
            }
            ledger.charge(Component::ClusterSearch, self.device.mem_scan_cost(bytes));

            let local = self.scorer.top_k(query, &self.cluster_embs[c], k)?;
            for (li, s) in local {
                all_hits.push((meta.chunk_ids[li], s));
            }
        }

        let n = all_hits.len();
        let scores: Vec<f32> = all_hits.iter().map(|&(_, s)| s).collect();
        let top = vecmath::top_k(&scores, n, k);
        let hits = top.into_iter().map(|(i, s)| (all_hits[i].0, s)).collect();

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events,
            intents: Vec::new(),
            shard_walks: Vec::new(),
        })
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        self.clusters.centroid_bytes()
            + self
                .cluster_embs
                .iter()
                .map(|m| m.bytes())
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetProfile, DeviceProfile};
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::{shared_memory, EmbedSource};
    use crate::testutil::shared_compute;
    use std::sync::Arc;

    fn build_tiny() -> (Corpus, IvfIndex, Arc<EmbeddingMatrix>, Embedder) {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        let scorer = Scorer::new(compute);
        let km = kmeans(
            &emb,
            &KMeansConfig {
                n_clusters: profile.n_topics,
                iterations: 6,
                seed: 1,
                init: None,
            },
            &scorer,
        )
        .unwrap();
        let device = DeviceProfile::jetson_orin_nano();
        let set = ClusterSet::build(&corpus, km.centroids, &km.assignment, &device);
        let source = EmbedSource::Prebuilt(emb.clone());
        let cluster_embs: Vec<EmbeddingMatrix> = set
            .clusters
            .iter()
            .map(|m| source.cluster_embeddings(m).unwrap())
            .collect();
        let idx = IvfIndex::new(
            set,
            cluster_embs,
            scorer,
            shared_memory(64 << 20),
            device,
            4,
        );
        (corpus, idx, emb, embedder)
    }

    #[test]
    fn retrieves_target_chunk_for_derived_query() {
        let (corpus, idx, _emb, embedder) = build_tiny();
        // Query = exact text of a chunk: its own embedding must win.
        let target = 100u32;
        let q = embedder.embed_one(&corpus.chunks[target as usize].text).unwrap();
        let out = idx.search(&q, 5).unwrap();
        assert!(
            out.hits.iter().any(|&(id, _)| id == target),
            "target {target} not in top-5: {:?}",
            out.hits
        );
        assert_eq!(out.probed.len(), 4);
    }

    #[test]
    fn charges_centroid_and_cluster_components() {
        let (_, idx, emb, _) = build_tiny();
        let q = emb.row(0).to_vec();
        let out = idx.search(&q, 3).unwrap();
        assert!(out.ledger.component(Component::CentroidProbe).as_nanos() > 0);
        assert!(out.ledger.component(Component::ClusterSearch).as_nanos() > 0);
    }

    #[test]
    fn thrash_under_tight_memory() {
        let (_, idx0, emb, _) = build_tiny();
        // Rebuild with a memory budget far below the embedding size.
        let idx = IvfIndex::new(
            idx0.clusters,
            idx0.cluster_embs,
            idx0.scorer,
            shared_memory(8 << 10), // 8 KiB
            idx0.device,
            4,
        );
        let q = emb.row(1).to_vec();
        idx.search(&q, 3).unwrap();
        let out = idx.search(&q, 3).unwrap();
        assert!(out.events.thrash_faults > 0);
        assert!(out.ledger.component(Component::Thrash).as_nanos() > 0);
    }

    #[test]
    fn warm_clusters_do_not_refault() {
        let (_, idx, emb, _) = build_tiny();
        let q = emb.row(2).to_vec();
        idx.search(&q, 3).unwrap();
        let out = idx.search(&q, 3).unwrap();
        assert_eq!(out.events.thrash_faults, 0);
    }

    #[test]
    fn resident_bytes_counts_both_levels() {
        let (_, idx, emb, _) = build_tiny();
        assert!(idx.resident_bytes() > emb.bytes());
    }

    #[test]
    fn hits_sorted_descending() {
        let (_, idx, emb, _) = build_tiny();
        let out = idx.search(emb.row(5), 10).unwrap();
        for w in out.hits.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
