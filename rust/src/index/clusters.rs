//! Shared two-level index structure: first-level centroids + per-cluster
//! metadata (paper §5.1).
//!
//! The metadata mirrors what EdgeRAG keeps resident: for every cluster the
//! chunk references, total text size, and the *profiled embedding
//! generation latency* computed at indexing time (used by selective
//! storage and the cost-aware cache). Actual second-level embeddings are
//! deliberately NOT stored here — each index configuration decides where
//! they live (memory / storage / generated online).

use std::sync::Arc;

use anyhow::Result;

use crate::config::DeviceProfile;
use crate::data::Corpus;
use crate::embedding::Embedder;
use crate::simtime::SimDuration;
use crate::vecmath::EmbeddingMatrix;

/// Per-cluster resident metadata.
#[derive(Debug, Clone)]
pub struct ClusterMeta {
    pub id: u32,
    /// Global chunk ids of the cluster's members, in gather order.
    pub chunk_ids: Vec<u32>,
    /// Total characters of member chunk texts (gen-cost driver).
    pub chars: u64,
    /// Profiled embedding-generation latency (paper Fig. 5 quantity).
    pub gen_cost: SimDuration,
}

impl ClusterMeta {
    pub fn len(&self) -> usize {
        self.chunk_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunk_ids.is_empty()
    }

    /// Bytes of this cluster's embeddings (f32 × dim × members).
    pub fn emb_bytes(&self, dim: usize) -> u64 {
        (self.chunk_ids.len() * dim * 4) as u64
    }
}

/// First-level centroids + second-level metadata.
#[derive(Debug)]
pub struct ClusterSet {
    pub centroids: EmbeddingMatrix,
    pub clusters: Vec<ClusterMeta>,
}

impl ClusterSet {
    /// Assemble from a k-means assignment over the corpus.
    pub fn build(
        corpus: &Corpus,
        centroids: EmbeddingMatrix,
        assignment: &[u32],
        device: &DeviceProfile,
    ) -> ClusterSet {
        assert_eq!(assignment.len(), corpus.len());
        let k = centroids.len();
        let mut clusters: Vec<ClusterMeta> = (0..k)
            .map(|id| ClusterMeta {
                id: id as u32,
                chunk_ids: Vec::new(),
                chars: 0,
                gen_cost: SimDuration::ZERO,
            })
            .collect();
        for (i, &a) in assignment.iter().enumerate() {
            let c = &mut clusters[a as usize];
            c.chunk_ids.push(i as u32);
            c.chars += corpus.chunks[i].chars();
        }
        for c in &mut clusters {
            c.gen_cost = device.embed_gen_cost(c.chars);
        }
        ClusterSet {
            centroids,
            clusters,
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Bytes the always-resident first level occupies.
    pub fn centroid_bytes(&self) -> u64 {
        self.centroids.bytes()
    }

    /// Total second-level embedding bytes (what the IVF baseline keeps in
    /// memory and EdgeRAG prunes).
    pub fn total_emb_bytes(&self, dim: usize) -> u64 {
        self.clusters.iter().map(|c| c.emb_bytes(dim)).sum()
    }
}

/// Where a cluster's second-level embeddings come from when needed.
///
/// `Prebuilt` reuses the build-time embedding matrix — valid because
/// generation is deterministic (verified by `edge_vs_oracle` tests), and
/// necessary to keep figure-scale benchmarks tractable on this testbed.
/// `Live` really re-runs the embedding model through PJRT, exactly like a
/// deployment would; with a `batcher` attached, concurrent queries'
/// on-demand cluster re-embeddings coalesce into fused kernel batches
/// through the cross-query scheduler's embed stage (bit-identical rows —
/// see [`crate::sched`]).
#[derive(Clone)]
pub enum EmbedSource {
    Prebuilt(Arc<EmbeddingMatrix>),
    Live {
        embedder: Embedder,
        texts: Arc<Vec<String>>,
        /// Optional cross-query embed stage; None embeds inline.
        batcher: Option<Arc<crate::sched::EmbedBatcher>>,
    },
}

impl EmbedSource {
    /// Materialize the embeddings of `meta`'s member chunks (gather order).
    pub fn cluster_embeddings(&self, meta: &ClusterMeta) -> Result<EmbeddingMatrix> {
        match self {
            EmbedSource::Prebuilt(all) => {
                let mut m = EmbeddingMatrix::with_capacity(all.dim, meta.len());
                for &cid in &meta.chunk_ids {
                    m.push(all.row(cid as usize));
                }
                Ok(m)
            }
            EmbedSource::Live {
                embedder,
                texts,
                batcher,
            } => {
                let refs: Vec<&str> = meta
                    .chunk_ids
                    .iter()
                    .map(|&cid| texts[cid as usize].as_str())
                    .collect();
                match batcher {
                    Some(b) => b.embed_texts(&refs),
                    None => embedder.embed_texts(&refs),
                }
            }
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            EmbedSource::Prebuilt(m) => m.dim,
            EmbedSource::Live { embedder, .. } => embedder.dim(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;
    use crate::data::Rng;

    fn fake_set(n_chunks: usize, k: usize) -> (Corpus, ClusterSet) {
        let mut p = DatasetProfile::tiny();
        p.n_chunks = n_chunks;
        let corpus = Corpus::generate(&p);
        let dim = 8;
        let mut rng = Rng::new(5);
        let mut centroids = EmbeddingMatrix::new(dim);
        for _ in 0..k {
            let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            centroids.push(&row);
        }
        let assignment: Vec<u32> = (0..n_chunks).map(|i| (i % k) as u32).collect();
        let set = ClusterSet::build(
            &corpus,
            centroids,
            &assignment,
            &DeviceProfile::jetson_orin_nano(),
        );
        (corpus, set)
    }

    #[test]
    fn members_partition_the_corpus() {
        let (corpus, set) = fake_set(128, 7);
        let mut seen = vec![false; corpus.len()];
        for c in &set.clusters {
            for &id in &c.chunk_ids {
                assert!(!seen[id as usize], "chunk {id} in two clusters");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "chunk missing from all clusters");
    }

    #[test]
    fn chars_and_gen_cost_consistent() {
        let (corpus, set) = fake_set(64, 4);
        let dev = DeviceProfile::jetson_orin_nano();
        for c in &set.clusters {
            let want: u64 = c
                .chunk_ids
                .iter()
                .map(|&id| corpus.chunks[id as usize].chars())
                .sum();
            assert_eq!(c.chars, want);
            assert_eq!(c.gen_cost, dev.embed_gen_cost(want));
        }
    }

    #[test]
    fn byte_accounting() {
        let (_, set) = fake_set(100, 5);
        let dim = 8;
        assert_eq!(set.total_emb_bytes(dim), (100 * dim * 4) as u64);
        assert_eq!(set.centroid_bytes(), (5 * dim * 4) as u64);
    }

    #[test]
    fn prebuilt_source_gathers_rows() {
        let (_, set) = fake_set(32, 3);
        let dim = 8;
        let mut all = EmbeddingMatrix::new(dim);
        for i in 0..32 {
            all.push(&vec![i as f32; dim]);
        }
        let src = EmbedSource::Prebuilt(Arc::new(all));
        let c = &set.clusters[1];
        let m = src.cluster_embeddings(c).unwrap();
        assert_eq!(m.len(), c.len());
        for (j, &cid) in c.chunk_ids.iter().enumerate() {
            assert_eq!(m.row(j)[0], cid as f32);
        }
    }
}
