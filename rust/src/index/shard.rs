//! The sharded EdgeRAG index: clusters partitioned across `N`
//! independently locked shards so one query fans its probed clusters out
//! to a scoped worker pool and structural updates stall only the owning
//! shard.
//!
//! ## Why shard
//!
//! EdgeRAG's retrieval splits into a centroid probe plus per-cluster
//! work (load / cache peek / online generation, then an in-cluster
//! scan). The per-cluster stage is embarrassingly parallel, but a
//! single [`EdgeIndex`] walks all probed clusters on one thread and all
//! queries share one cache lock, one threshold lock and one write lease
//! for updates. [`ShardedEdgeIndex`] partitions clusters round-robin
//! across `N` shards — each shard is a complete [`EdgeIndex`] over its
//! subset, with its **own** cost-aware cache, adaptive-threshold
//! controller and update generation behind its **own** `RwLock` — so:
//!
//! * a query's probed clusters execute as per-shard cluster walks, in
//!   parallel on the shard pool, and the per-shard top-k heaps merge
//!   back in probe order;
//! * the centroid probe scores against a **lock-free [`ProbeTable`]
//!   snapshot** (invalidated by structural updates, rebuilt lazily by
//!   the next probe), so a newly arriving query takes no shard lease at
//!   all during its probe and never waits behind an in-flight insert;
//! * an online insert/remove takes only the owning shard's write lease:
//!   cluster walks and intent commits touching other shards proceed
//!   concurrently;
//! * each shard's deferred [`CacheIntent`] commits independently under
//!   that shard's locks.
//!
//! ## Equivalence with the unsharded index
//!
//! Sharding must not change retrieval results. Three mechanisms make the
//! sharded walk reproduce the sequential one exactly:
//!
//! 1. probes are selected from a **global** score table (the
//!    [`ProbeTable`] snapshot holds every shard's centroids spliced into
//!    global cluster order), so the probed set and order — ties
//!    included — match the unsharded probe;
//! 2. every shard runs the *same* cluster-walk code
//!    ([`EdgeIndex::search_clusters`]) over its subsequence of the probe
//!    order, tagging each cluster's candidates with their global probe
//!    position;
//! 3. the merge re-sorts candidate groups by probe position before the
//!    final top-k, recreating the exact candidate order (and therefore
//!    the exact ties) a sequential walk produces.
//!
//! With `shards = 1` the whole path degenerates to a single
//! [`EdgeIndex`] walk and is bit-identical to it. With `shards > 1` the
//! top-k ids/scores are still identical; only cache *capacity placement*
//! changes (the byte budget splits evenly across shards, and each shard
//! adapts its own threshold from the queries that touch it).
//!
//! ## Cluster ids
//!
//! Shards use dense local cluster ids internally. The global id of local
//! cluster `l` in shard `s` is `l × n_shards + s` (so the initial
//! round-robin partition maps global id `g` to shard `g % n_shards`,
//! local `g / n_shards`, and splits allocate fresh globally unique ids).
//! [`SearchOutcome::probed`] and the cluster ids returned by
//! [`ShardedEdgeIndex::insert_chunk`] are global ids.
//!
//! ## Locking
//!
//! Lock order is strictly `shard RwLock → controller → cache → memory
//! model`, and no thread ever holds two shard locks at once (probing
//! reads only the snapshot; routing and snapshot rebuilds visit shards
//! sequentially, one read lock at a time; fan-out workers each take
//! exactly one). See `docs/ARCHITECTURE.md` for the full hierarchy
//! including the engine lease above this one.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};

use anyhow::Result;

use crate::cache::CacheStats;
use crate::config::{DeviceProfile, IndexKind, RetrievalConfig};
use crate::index::edge::{ClusterHits, ClusterWalk};
use crate::index::{
    CacheIntent, ClusterMeta, ClusterSet, EdgeIndex, EmbedSource, ProbeTable, Scorer,
    SearchEvents, SearchOutcome, SharedMemory, VectorIndex,
};
use crate::pool::{Job, SubmitError, WorkerPool};
use crate::simtime::{Component, LatencyLedger, SimDuration};
use crate::storage::BlobStore;
use crate::vecmath::{self, EmbeddingMatrix};

/// Hard ceiling on the shard count: shard `i` namespaces its memory-model
/// regions at `i << 24`, leaving 24 bits of local cluster ids per shard.
pub const MAX_SHARDS: usize = 256;

// ---------------------------------------------------------------------------
// Per-shard serving counters
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct ShardCounters {
    probes: AtomicU64,
    cache_hits: AtomicU64,
    generated: AtomicU64,
    loaded: AtomicU64,
    inserts: AtomicU64,
    removes: AtomicU64,
}

/// One shard's serving statistics snapshot (the `stats` endpoint's
/// per-shard rows).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// Active (non-tombstone) clusters currently owned by this shard.
    pub clusters: usize,
    /// Probed clusters routed to this shard so far.
    pub probes: u64,
    /// Embedding-cache hits served by this shard.
    pub cache_hits: u64,
    /// Clusters this shard generated online.
    pub generated: u64,
    /// Clusters this shard loaded from its blob store.
    pub loaded: u64,
    /// Online insertions routed to this shard.
    pub inserts: u64,
    /// Online removals routed to this shard.
    pub removes: u64,
    /// This shard's current adaptive caching threshold (ms).
    pub threshold_ms: f64,
    /// Bytes resident in this shard's embedding cache.
    pub cache_used_bytes: u64,
}

// ---------------------------------------------------------------------------
// The sharded index
// ---------------------------------------------------------------------------

/// Clusters partitioned across `N` independently locked [`EdgeIndex`]
/// shards (see the module docs for the design and equivalence argument).
pub struct ShardedEdgeIndex {
    kind: IndexKind,
    /// `Arc` so fan-out jobs on the pool can borrow shards without tying
    /// their lifetimes to the calling query.
    shards: Arc<Vec<RwLock<EdgeIndex>>>,
    counters: Vec<ShardCounters>,
    nprobe: usize,
    device: DeviceProfile,
    scorer: Scorer,
    /// Persistent pool executing per-(query, shard) cluster walks. Any
    /// worker may serve any shard (walks take only shard read leases).
    pool: WorkerPool,
    /// The spliced first-level snapshot queries probe against **without
    /// any shard lease** — a probing query never queues behind an
    /// in-flight structural update. Inserts/removes only mark it stale
    /// (`table_stale`); the next probe rebuilds it lazily, so an update
    /// burst pays one rebuild, not one per update. The lock is held only
    /// to clone or swap the `Arc`.
    probe_table: RwLock<Arc<ProbeTable>>,
    /// Set by structural updates after their shard write completes;
    /// cleared by the (serialized) lazy rebuild.
    table_stale: AtomicBool,
    /// Serializes snapshot rebuilds so concurrent probes after an update
    /// trigger exactly one rebuild and later rebuilds see every
    /// completed update.
    table_rebuild: Mutex<()>,
}

impl ShardedEdgeIndex {
    /// Partition `clusters` round-robin across `shards` shards and build
    /// one [`EdgeIndex`] per shard. The cache byte budget in `retrieval`
    /// splits evenly; `blob_dir` (required when `kind` uses selective
    /// storage) gets one `shard{i}` subdirectory per shard.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        kind: IndexKind,
        clusters: ClusterSet,
        source: EmbedSource,
        blob_dir: Option<&Path>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
        retrieval: &RetrievalConfig,
        store_limit: SimDuration,
        slo: SimDuration,
        shards: usize,
    ) -> Result<Self> {
        let k = shards.max(1);
        anyhow::ensure!(k <= MAX_SHARDS, "at most {MAX_SHARDS} shards");
        anyhow::ensure!(
            clusters.n_clusters() < (1 << 24),
            "cluster ids must fit the 24-bit per-shard namespace"
        );
        let dim = clusters.centroids.dim;

        // Round-robin partition: global cluster `g` → shard `g % k`,
        // local id `g / k`. Round-robin (rather than contiguous ranges)
        // balances the tail-heavy cluster-size distribution in
        // expectation.
        let mut parts: Vec<(EmbeddingMatrix, Vec<ClusterMeta>)> = (0..k)
            .map(|_| (EmbeddingMatrix::new(dim), Vec::new()))
            .collect();
        for (g, meta) in clusters.clusters.iter().enumerate() {
            let (centroids, metas) = &mut parts[g % k];
            centroids.push(clusters.centroids.row(g));
            metas.push(ClusterMeta {
                id: metas.len() as u32,
                chunk_ids: meta.chunk_ids.clone(),
                chars: meta.chars,
                gen_cost: meta.gen_cost,
            });
        }

        // Each shard gets an even slice of the cache byte budget.
        let mut per_shard = retrieval.clone();
        per_shard.cache_capacity_bytes = (retrieval.cache_capacity_bytes / k as u64).max(1);

        let mut built = Vec::with_capacity(k);
        for (i, (centroids, metas)) in parts.into_iter().enumerate() {
            let set = ClusterSet {
                centroids,
                clusters: metas,
            };
            let blob = if kind.uses_storage() {
                let dir = blob_dir
                    .ok_or_else(|| anyhow::anyhow!("selective storage requires a blob dir"))?;
                Some(BlobStore::open(&dir.join(format!("shard{i}")), dim)?)
            } else {
                None
            };
            let mut shard = EdgeIndex::build(
                kind,
                set,
                source.clone(),
                blob,
                scorer.clone(),
                memory.clone(),
                device.clone(),
                &per_shard,
                store_limit,
                slo,
            )?;
            shard.set_region_base((i as u32) << 24);
            built.push(RwLock::new(shard));
        }

        // Pool sizing: the calling thread always walks one shard-group
        // itself, so at most `k − 1` walks per query run remotely; more
        // workers than cores just adds scheduler churn.
        let workers = k
            .saturating_sub(1)
            .min(crate::config::default_shards());
        let index = ShardedEdgeIndex {
            kind,
            shards: Arc::new(built),
            counters: (0..k).map(|_| ShardCounters::default()).collect(),
            nprobe: retrieval.nprobe,
            device,
            scorer,
            pool: WorkerPool::new("edgerag-shard", workers),
            probe_table: RwLock::new(Arc::new(ProbeTable {
                centroids: EmbeddingMatrix::new(dim),
                ids: Vec::new(),
                active: Vec::new(),
                centroid_bytes: 0,
                generation: 0,
            })),
            table_stale: AtomicBool::new(false),
            table_rebuild: Mutex::new(()),
        };
        {
            let _serial = index.table_rebuild.lock().unwrap();
            index.rebuild_probe_table();
        }
        Ok(index)
    }

    /// The current probe snapshot, rebuilding lazily if a structural
    /// update invalidated it. The common (fresh) path is one atomic load
    /// plus an `Arc` clone.
    fn probe_table_current(&self) -> Arc<ProbeTable> {
        if self.table_stale.load(Ordering::Acquire) {
            let _serial = self.table_rebuild.lock().unwrap();
            // Claim-then-build: clear the flag *before* reading shard
            // state, so an update landing mid-rebuild re-marks it and
            // the next probe rebuilds again — a completed update can
            // never be silently missed.
            if self.table_stale.swap(false, Ordering::AcqRel) {
                self.rebuild_probe_table();
            }
        }
        self.probe_table.read().unwrap().clone()
    }

    /// Rebuild the spliced probe snapshot from the current shard state.
    /// Caller must hold `table_rebuild`; takes one shard read lease at a
    /// time — never two at once, per the lock hierarchy.
    fn rebuild_probe_table(&self) {
        let k = self.shards.len();
        // Per-shard copies first (one lease at a time), splice after.
        let mut parts: Vec<(EmbeddingMatrix, Vec<bool>)> = Vec::with_capacity(k);
        let mut centroid_bytes = 0u64;
        let mut generation = 0u64;
        let mut width = 0usize;
        for shard in self.shards.iter() {
            let guard = shard.read().unwrap();
            centroid_bytes += guard.clusters().centroid_bytes();
            generation += guard.update_generation();
            let centroids = guard.clusters().centroids.clone();
            let active = guard.active_flags().to_vec();
            width = width.max(centroids.len());
            parts.push((centroids, active));
        }
        // Interleave into ascending global-id order (`l × k + s`) — the
        // exact traversal order the lease-based probe spliced in, so
        // `top_k`'s lower-index tie preference is preserved.
        let dim = parts.first().map_or(0, |(c, _)| c.dim);
        let total: usize = parts.iter().map(|(c, _)| c.len()).sum();
        let mut centroids = EmbeddingMatrix::with_capacity(dim, total);
        let mut ids = Vec::new();
        let mut active = Vec::new();
        for l in 0..width {
            for (s, (cent, act)) in parts.iter().enumerate() {
                if l < cent.len() {
                    centroids.push(cent.row(l));
                    ids.push((l * k + s) as u32);
                    active.push(act[l]);
                }
            }
        }
        *self.probe_table.write().unwrap() = Arc::new(ProbeTable {
            centroids,
            ids,
            active,
            centroid_bytes,
            generation,
        });
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Owning shard of a global cluster id.
    pub fn shard_of(&self, global_cluster: u32) -> usize {
        global_cluster as usize % self.shards.len()
    }

    /// Run `f` against one shard under its read lease (introspection and
    /// tests; holding the guard blocks only that shard's writers).
    pub fn with_shard<R>(&self, shard: usize, f: impl FnOnce(&EdgeIndex) -> R) -> R {
        f(&self.shards[shard].read().unwrap())
    }

    /// Override the probe width (harness sweeps).
    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe;
    }

    /// Pin every shard's caching threshold and disable adaptation (the
    /// Fig. 7 sweep, applied uniformly).
    pub fn pin_threshold(&self, threshold_ms: f64) {
        for shard in self.shards.iter() {
            shard.write().unwrap().pin_threshold(threshold_ms);
        }
    }

    /// Aggregate cache statistics across shards (None when this
    /// configuration has no cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        if !self.kind.uses_cache() {
            return None;
        }
        let mut total = CacheStats::default();
        for shard in self.shards.iter() {
            if let Some(s) = shard.read().unwrap().cache_stats() {
                total.hits += s.hits;
                total.misses += s.misses;
                total.insertions += s.insertions;
                total.evictions += s.evictions;
                total.rejected_below_threshold += s.rejected_below_threshold;
            }
        }
        Some(total)
    }

    /// Total bytes resident across all shard caches.
    pub fn cache_used_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().cache_used_bytes())
            .sum()
    }

    /// Global ids of every cluster currently resident in any shard's
    /// cache, sorted (equivalence tests, stats).
    pub fn cached_clusters(&self) -> Vec<u32> {
        let k = self.shards.len() as u32;
        let mut all = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            for local in shard.read().unwrap().cached_clusters() {
                all.push(local * k + s as u32);
            }
        }
        all.sort_unstable();
        all
    }

    /// Total clusters persisted across all shard blob stores.
    pub fn stored_clusters(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().stored_clusters())
            .sum()
    }

    /// Total bytes persisted across all shard blob stores.
    pub fn stored_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().stored_bytes())
            .sum()
    }

    /// Mean adaptive threshold across shards (each shard adapts its own;
    /// the scalar is for dashboards — see [`ShardedEdgeIndex::shard_stats`]
    /// for the per-shard values).
    pub fn threshold_ms(&self) -> f64 {
        let sum: f64 = self
            .shards
            .iter()
            .map(|s| s.read().unwrap().threshold_ms())
            .sum();
        sum / self.shards.len() as f64
    }

    /// Active (non-tombstone) clusters across all shards.
    pub fn active_clusters(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().active_clusters())
            .sum()
    }

    /// Global cluster currently holding `chunk`, if any.
    pub fn cluster_of(&self, chunk: u32) -> Option<u32> {
        let k = self.shards.len() as u32;
        for (s, shard) in self.shards.iter().enumerate() {
            if let Some(local) = shard.read().unwrap().cluster_of(chunk) {
                return Some(local * k + s as u32);
            }
        }
        None
    }

    /// Per-shard serving statistics.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let guard = shard.read().unwrap();
                let c = &self.counters[i];
                ShardStats {
                    shard: i,
                    clusters: guard.active_clusters(),
                    probes: c.probes.load(Ordering::Relaxed),
                    cache_hits: c.cache_hits.load(Ordering::Relaxed),
                    generated: c.generated.load(Ordering::Relaxed),
                    loaded: c.loaded.load(Ordering::Relaxed),
                    inserts: c.inserts.load(Ordering::Relaxed),
                    removes: c.removes.load(Ordering::Relaxed),
                    threshold_ms: guard.threshold_ms(),
                    cache_used_bytes: guard.cache_used_bytes(),
                }
            })
            .collect()
    }

    /// The shard an insertion of `emb` would route to (nearest active
    /// centroid across all shards).
    pub fn route(&self, emb: &[f32]) -> Result<usize> {
        let mut best: Option<(usize, f32)> = None;
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            if let Some(&(_, score)) = guard.probe(emb, 1)?.first() {
                // NEG_INFINITY marks a shard whose clusters are all
                // tombstones — never a routing target.
                let better = match best {
                    None => true,
                    Some((_, b)) => score > b,
                };
                if score.is_finite() && better {
                    best = Some((s, score));
                }
            }
        }
        best.map(|(s, _)| s)
            .ok_or_else(|| anyhow::anyhow!("no active clusters"))
    }

    /// Insert a chunk (§5.4), write-leasing **only the owning shard**:
    /// queries to other shards proceed concurrently. `id` must be
    /// globally fresh (the serving engine allocates ids from its shared
    /// text store; duplicate detection here is per-shard only). Returns
    /// the global cluster id the chunk joined.
    pub fn insert_chunk(&self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        let target = self.route(emb)?;
        // Routing released its read locks before this write acquire; the
        // shard re-probes internally under the write lease, so a racing
        // merge/split inside the shard cannot misroute the chunk.
        let local = self.shards[target].write().unwrap().insert_chunk(id, text, emb)?;
        self.counters[target].inserts.fetch_add(1, Ordering::Relaxed);
        // Invalidate the lock-free probe snapshot (marked after the
        // write lease is released; the next probe rebuilds — queries on
        // the old snapshot behave like queries that arrived just before
        // this insert).
        self.table_stale.store(true, Ordering::Release);
        Ok(local * self.shards.len() as u32 + target as u32)
    }

    /// Remove a chunk (§5.4), write-leasing only the shard that owns it.
    /// Returns false if the chunk is unknown.
    pub fn remove_chunk(&self, id: u32) -> Result<bool> {
        // Chunks never migrate across shards (merges and splits are
        // intra-shard), so the owner found here is stable.
        let owner = (0..self.shards.len())
            .find(|&s| self.shards[s].read().unwrap().cluster_of(id).is_some());
        let Some(s) = owner else { return Ok(false) };
        let removed = self.shards[s].write().unwrap().remove_chunk(id)?;
        if removed {
            self.counters[s].removes.fetch_add(1, Ordering::Relaxed);
            self.table_stale.store(true, Ordering::Release);
        }
        Ok(removed)
    }

    /// Search then immediately commit every shard intent — the
    /// single-caller convenience path (tests, tools), mirroring
    /// [`EdgeIndex::search_and_commit`].
    pub fn search_and_commit(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let out = self.search(query, k)?;
        self.commit(&out.intents, out.ledger.retrieval());
        Ok(out)
    }

    /// Execute the per-shard cluster walks, fanning all but the first
    /// group out to the pool. Returns `(shard, walk)` pairs in arbitrary
    /// order.
    fn run_walks(
        &self,
        query: &[f32],
        work: Vec<(usize, Vec<(u32, u32)>)>,
        k: usize,
    ) -> Result<Vec<(usize, ClusterWalk)>> {
        let mut walks = Vec::with_capacity(work.len());
        if work.len() <= 1 || self.pool.workers() == 0 {
            for (s, group) in work {
                let walk = self.shards[s].read().unwrap().search_clusters(query, &group, k)?;
                walks.push((s, walk));
            }
            return Ok(walks);
        }

        let query: Arc<Vec<f32>> = Arc::new(query.to_vec());
        let (tx, rx) = mpsc::channel::<Result<(usize, ClusterWalk)>>();
        let mut iter = work.into_iter();
        let first = iter.next().expect("work checked non-empty");
        let mut remote = 0usize;
        for (s, group) in iter {
            let shards = self.shards.clone();
            let q = query.clone();
            let tx = tx.clone();
            let job: Job = Box::new(move || {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shards[s].read().unwrap().search_clusters(&q, &group, k)
                }));
                let msg = match res {
                    Ok(r) => r.map(|walk| (s, walk)),
                    Err(_) => Err(anyhow::anyhow!("shard {s} cluster walk panicked")),
                };
                let _ = tx.send(msg);
            });
            // A refused job (no workers / pool gone) runs on this thread;
            // its result still arrives through the channel.
            if let Err(SubmitError::Full(job) | SubmitError::Closed(job)) = self.pool.submit(job)
            {
                job();
            }
            remote += 1;
        }
        drop(tx);

        // Walk the first group on the calling thread while workers run
        // theirs, then collect.
        let (s, group) = first;
        let walk = self.shards[s].read().unwrap().search_clusters(&query, &group, k)?;
        walks.push((s, walk));
        for _ in 0..remote {
            let pair = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("shard pool disconnected"))??;
            walks.push(pair);
        }
        Ok(walks)
    }

    /// Search using centroid scores a caller already computed against a
    /// [`ProbeTable`] snapshot of this index ([`crate::sched`] computes
    /// them for several queries in one fused `sim_{A}x{N}` call).
    /// Identical to [`VectorIndex::search`] whenever `scores` equals the
    /// snapshot's masked scores for this query — probe selection (ties
    /// included), the fan-out walks and the probe-order merge are the
    /// same code paths.
    pub fn search_scored(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        anyhow::ensure!(
            scores.len() == table.len(),
            "probe scores ({}) must align with the probe table ({})",
            scores.len(),
            table.len()
        );
        let n_shards = self.shards.len();
        let mut ledger = LatencyLedger::new();

        // One modeled charge for the whole (distributed but byte-
        // identical) centroid table.
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(table.centroid_bytes),
        );
        let probes = vecmath::top_k(scores, scores.len(), self.nprobe);

        // Group the probe list by owning shard, preserving each shard's
        // subsequence of the global probe order.
        let mut probed = Vec::with_capacity(probes.len());
        let mut groups: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n_shards];
        for (pos, &(i, _)) in probes.iter().enumerate() {
            let g = table.ids[i] as usize;
            probed.push(g as u32);
            groups[g % n_shards].push((pos as u32, (g / n_shards) as u32));
        }
        let work: Vec<(usize, Vec<(u32, u32)>)> = groups
            .into_iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .collect();
        for (s, group) in &work {
            self.counters[*s]
                .probes
                .fetch_add(group.len() as u64, Ordering::Relaxed);
        }

        // Fan the cluster walks out and merge.
        let mut walks = self.run_walks(query, work, k)?;
        walks.sort_by_key(|&(s, _)| s); // deterministic intent order

        let mut events = SearchEvents::default();
        let mut intents = Vec::with_capacity(walks.len());
        let mut all_groups: Vec<ClusterHits> = Vec::new();
        for (s, mut walk) in walks {
            ledger.merge(&walk.ledger);
            events.generated += walk.events.generated;
            events.loaded += walk.events.loaded;
            events.cache_hits += walk.events.cache_hits;
            events.thrash_faults += walk.events.thrash_faults;
            let c = &self.counters[s];
            c.cache_hits
                .fetch_add(walk.events.cache_hits as u64, Ordering::Relaxed);
            c.generated
                .fetch_add(walk.events.generated as u64, Ordering::Relaxed);
            c.loaded
                .fetch_add(walk.events.loaded as u64, Ordering::Relaxed);
            walk.intent.shard = s;
            intents.push(walk.intent);
            all_groups.append(&mut walk.groups);
        }

        // Merge the per-shard heaps: candidates re-sorted into global
        // probe order make the final top-k (ties included) identical to a
        // sequential walk's.
        all_groups.sort_by_key(|g| g.probe_pos);
        let all_hits: Vec<(u32, f32)> = all_groups.into_iter().flat_map(|g| g.hits).collect();
        let hits = vecmath::top_k_hits(all_hits, k);

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events,
            intents,
        })
    }
}

impl VectorIndex for ShardedEdgeIndex {
    fn kind(&self) -> IndexKind {
        self.kind
    }

    /// (1) centroid probe against the lock-free spliced snapshot (global
    /// cluster order, tombstones masked — probe selection and tie-breaks
    /// identical to the unsharded index, and **no shard lease is taken**,
    /// so a probing query never waits behind an in-flight insert), then
    /// (2..6) per-shard fan-out walks and the probe-order merge.
    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let table = self.probe_table_current();
        let scores = table.masked_scores(&self.scorer, query)?;
        self.search_scored(query, &table, &scores, k)
    }

    /// Commit each shard's intent independently: only that shard's
    /// controller/cache locks are taken, so commits for different shards
    /// (from this or other queries) never serialize on each other.
    fn commit(&self, intents: &[CacheIntent], retrieval: SimDuration) {
        for intent in intents {
            let Some(shard) = self.shards.get(intent.shard) else {
                continue;
            };
            shard.read().unwrap().commit_intent(intent, retrieval);
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().resident_bytes())
            .sum()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        ShardedEdgeIndex::cache_stats(self)
    }

    fn cache_used_bytes(&self) -> u64 {
        ShardedEdgeIndex::cache_used_bytes(self)
    }

    fn cached_clusters(&self) -> Vec<u32> {
        ShardedEdgeIndex::cached_clusters(self)
    }

    fn stored_clusters(&self) -> usize {
        ShardedEdgeIndex::stored_clusters(self)
    }

    fn stored_bytes(&self) -> u64 {
        ShardedEdgeIndex::stored_bytes(self)
    }

    fn threshold_ms(&self) -> f64 {
        ShardedEdgeIndex::threshold_ms(self)
    }

    fn pin_threshold(&mut self, threshold_ms: f64) {
        ShardedEdgeIndex::pin_threshold(self, threshold_ms)
    }

    fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        Some(ShardedEdgeIndex::shard_stats(self))
    }

    fn supports_concurrent_updates(&self) -> bool {
        true
    }

    fn insert_chunk(&mut self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        ShardedEdgeIndex::insert_chunk(self, id, text, emb)
    }

    fn remove_chunk(&mut self, id: u32) -> Result<bool> {
        ShardedEdgeIndex::remove_chunk(self, id)
    }

    fn insert_chunk_concurrent(&self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        ShardedEdgeIndex::insert_chunk(self, id, text, emb)
    }

    fn remove_chunk_concurrent(&self, id: u32) -> Result<bool> {
        ShardedEdgeIndex::remove_chunk(self, id)
    }

    fn probe_table(&self) -> Option<Arc<ProbeTable>> {
        Some(self.probe_table_current())
    }

    fn search_with_scores(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        self.search_scored(query, table, scores, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::shared_memory;
    use crate::testutil::shared_compute;

    struct Fixture {
        corpus: Corpus,
        emb: Arc<EmbeddingMatrix>,
        device: DeviceProfile,
        scorer: Scorer,
        embedder: Embedder,
    }

    fn fixture() -> Fixture {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        Fixture {
            corpus,
            emb,
            device: DeviceProfile::jetson_orin_nano(),
            scorer: Scorer::new(compute),
            embedder,
        }
    }

    fn cluster_set(f: &Fixture) -> ClusterSet {
        let km = kmeans(
            &f.emb,
            &KMeansConfig {
                n_clusters: 8,
                iterations: 5,
                seed: 1,
                init: None,
            },
            &f.scorer,
        )
        .unwrap();
        ClusterSet::build(&f.corpus, km.centroids, &km.assignment, &f.device)
    }

    fn state_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("edgerag-shard-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn retrieval() -> RetrievalConfig {
        RetrievalConfig {
            nprobe: 4,
            ..Default::default()
        }
    }

    fn build_sharded(f: &Fixture, tag: &str, shards: usize) -> ShardedEdgeIndex {
        let dir = state_dir(tag);
        ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            shards,
        )
        .unwrap()
    }

    fn build_edge(f: &Fixture, tag: &str) -> EdgeIndex {
        let dir = state_dir(tag);
        let blob = BlobStore::open(&dir, f.scorer.dim()).unwrap();
        EdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(blob),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
        )
        .unwrap()
    }

    #[test]
    fn partition_covers_every_cluster() {
        let f = fixture();
        let set = cluster_set(&f);
        let total = set.n_clusters();
        let idx = build_sharded(&f, "part", 3);
        assert_eq!(idx.shards(), 3);
        let per_shard: usize = (0..3).map(|s| idx.with_shard(s, |e| e.clusters().n_clusters())).sum();
        assert_eq!(per_shard, total);
        // Every chunk is still owned by exactly one (global) cluster.
        for chunk in [0u32, 17, 101, 300] {
            let g = idx.cluster_of(chunk).expect("chunk routed");
            assert_eq!(idx.shard_of(g), g as usize % 3);
        }
    }

    #[test]
    fn single_shard_is_bit_identical_to_edge_index() {
        let f = fixture();
        let edge = build_edge(&f, "bit-e");
        let sharded = build_sharded(&f, "bit-s", 1);
        for i in [0usize, 17, 101, 300, 443] {
            let q = f.emb.row(i).to_vec();
            let a = edge.search(&q, 5).unwrap();
            let b = sharded.search(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.probed, b.probed, "query {i}");
            assert_eq!(a.ledger.total(), b.ledger.total(), "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.loaded, b.events.loaded, "query {i}");
            assert_eq!(b.intents.len(), 1);
            assert_eq!(b.intents[0].shard, 0);
        }
    }

    #[test]
    fn four_shards_identical_topk_and_admissions() {
        // The satellite equivalence property at unit scale: same corpus,
        // same queries → identical top-k and identical per-cluster cache
        // admissions for shards=1 vs shards=4 (thresholds pinned so the
        // per-shard feedback loops cannot diverge).
        let f = fixture();
        let one = build_sharded(&f, "eq1", 1);
        let four = build_sharded(&f, "eq4", 4);
        one.pin_threshold(0.0);
        four.pin_threshold(0.0);
        for i in 0..16usize {
            let q = f.emb.row(i * 30).to_vec();
            let a = one.search_and_commit(&q, 5).unwrap();
            let b = four.search_and_commit(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.cache_hits, b.events.cache_hits, "query {i}");
        }
        assert_eq!(one.cached_clusters(), four.cached_clusters());
    }

    #[test]
    fn insert_and_remove_route_to_owning_shard() {
        let f = fixture();
        let idx = build_sharded(&f, "ins", 4);
        let text = "a fresh shard-routed document with marker tokens zzshard yyshard";
        let emb = f.embedder.embed_one(text).unwrap();
        let id = f.corpus.len() as u32 + 7;
        let expected_shard = idx.route(&emb).unwrap();
        let cluster = idx.insert_chunk(id, text, &emb).unwrap();
        assert_eq!(idx.shard_of(cluster), expected_shard);
        assert_eq!(idx.cluster_of(id), Some(cluster));
        let out = idx.search_and_commit(&emb, 3).unwrap();
        assert_eq!(out.hits[0].0, id, "hits: {:?}", out.hits);
        let stats = idx.shard_stats();
        assert_eq!(stats[expected_shard].inserts, 1);
        assert!(idx.remove_chunk(id).unwrap());
        assert_eq!(idx.cluster_of(id), None);
        assert!(!idx.remove_chunk(id).unwrap(), "second remove is a no-op");
    }

    #[test]
    fn insert_does_not_block_readers_of_other_shards() {
        // The tentpole overlap property, made deterministic: hold a read
        // lease on a shard the insert does NOT own; the insert must still
        // complete.
        let f = fixture();
        let idx = Arc::new(build_sharded(&f, "overlap", 4));
        let text = "overlap probe document zzoverlap";
        let emb = f.embedder.embed_one(text).unwrap();
        let target = idx.route(&emb).unwrap();
        let other = (target + 1) % idx.shards();
        let id = f.corpus.len() as u32 + 11;
        idx.with_shard(other, |_held| {
            let (tx, rx) = mpsc::channel();
            let idx2 = idx.clone();
            let emb2 = emb.clone();
            let text2 = text.to_string();
            std::thread::spawn(move || {
                let _ = tx.send(idx2.insert_chunk(id, &text2, &emb2).map(|_| ()));
            });
            rx.recv_timeout(std::time::Duration::from_secs(10))
                .expect("insert must not block on an unrelated shard's read lease")
                .expect("insert succeeds");
        });
        assert_eq!(idx.cluster_of(id).map(|g| idx.shard_of(g)), Some(target));
    }

    #[test]
    fn concurrent_queries_and_inserts_stay_consistent() {
        let f = fixture();
        let idx = build_sharded(&f, "conc", 4);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| f.emb.row(i * 50).to_vec()).collect();
        let serial: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect())
            .collect();
        let base = f.corpus.len() as u32 + 100;
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let idx = &idx;
                let queries = &queries;
                scope.spawn(move || {
                    for _ in 0..3 {
                        for q in queries {
                            // Concurrent inserts may add hits but must
                            // never corrupt a search.
                            let out = idx.search_and_commit(q, 5).unwrap();
                            assert!(!out.hits.is_empty());
                        }
                    }
                });
            }
            let idx = &idx;
            let embedder = &f.embedder;
            scope.spawn(move || {
                for i in 0..10u32 {
                    let text = format!("concurrent insert number {i} marker zzconc{i}");
                    let emb = embedder.embed_one(&text).unwrap();
                    idx.insert_chunk(base + i, &text, &emb).unwrap();
                }
            });
        });
        // After the dust settles: serial agreement for the original
        // corpus' queries still holds on the top hit (inserted docs can
        // only displace weaker candidates), and every insert is routed.
        for (i, q) in queries.iter().enumerate() {
            let ids: Vec<u32> = idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect();
            assert_eq!(ids[0], serial[i][0], "query {i} top hit changed");
        }
        let total_inserts: u64 = idx.shard_stats().iter().map(|s| s.inserts).sum();
        assert_eq!(total_inserts, 10);
        for i in 0..10u32 {
            assert!(idx.cluster_of(base + i).is_some(), "insert {i} lost");
        }
    }

    #[test]
    fn probe_needs_no_shard_lease() {
        // ROADMAP deferred item (a): the centroid probe reads only the
        // lock-free snapshot — it must complete (and select exactly the
        // probes a full search selects) even while EVERY shard's write
        // lease is held by an in-flight structural update.
        let f = fixture();
        let idx = build_sharded(&f, "probe-free", 4);
        let q = f.emb.row(10).to_vec();
        let expect = idx.search(&q, 5).unwrap();
        let guards: Vec<_> = idx.shards.iter().map(|s| s.write().unwrap()).collect();
        let table = VectorIndex::probe_table(&idx).unwrap();
        let scores = table.masked_scores(&f.scorer, &q).unwrap();
        let probes = vecmath::top_k(&scores, scores.len(), 4);
        drop(guards);
        let probed: Vec<u32> = probes.iter().map(|&(i, _)| table.ids[i]).collect();
        assert_eq!(probed, expect.probed, "snapshot probe diverged");
    }

    #[test]
    fn remove_refreshes_probe_snapshot() {
        // Tombstoning a cluster must propagate into the lock-free
        // snapshot so later probes mask it out.
        let f = fixture();
        let idx = build_sharded(&f, "probe-refresh", 2);
        let before = VectorIndex::probe_table(&idx).unwrap();
        let live_before = before.active.iter().filter(|&&a| a).count();
        // Drain one cluster below MERGE_THRESHOLD to force a merge.
        let victim = idx.with_shard(0, |e| e.clusters().clusters[0].chunk_ids.clone());
        for &chunk in victim.iter().take(victim.len().saturating_sub(1)) {
            idx.remove_chunk(chunk).unwrap();
        }
        let after = VectorIndex::probe_table(&idx).unwrap();
        let live_after = after.active.iter().filter(|&&a| a).count();
        assert!(
            live_after < live_before,
            "merge must tombstone a cluster in the snapshot \
             ({live_before} -> {live_after})"
        );
    }

    #[test]
    fn rejects_too_many_shards() {
        let f = fixture();
        let dir = state_dir("max");
        let err = ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(&f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            MAX_SHARDS + 1,
        );
        assert!(err.is_err());
    }
}
